"""PhoneBit core: the paper's contribution as composable JAX modules.

C1  binary_ops          xor+popcount dot/matmul (Eqn 1)
C2  packing             channel compression, NHWC packed layout
C4  layer_integration   conv+BN+sign folded to integer thresholds (Eqns 3-9)
C6  binary_conv         packed conv / dense / OR-pool with in-register packing
C8  bitplanes           first-layer bit-plane decomposition (Eqn 2)
C9  converter           trained params -> compressed PhoneBit artifact (Fig 2)
     bnn_model          spec -> training forward / packed inference forward
     binarize           sign + straight-through estimator (training substrate)
"""

from repro.core import (binarize, binary_conv, binary_ops, bitplanes,
                        bnn_model, converter, layer_integration, packing)

__all__ = [
    "binarize", "binary_conv", "binary_ops", "bitplanes", "bnn_model",
    "converter", "layer_integration", "packing",
]
