"""Layer integration (paper §V-B + §VI-C, Eqns 3-9).

Conv + batch-norm + binarization are folded into a single operator.  The
paper computes, offline,

    xi = mu - beta * sigma / gamma - b                      (Eqn 6)

and evaluates Eqn (8) at runtime with the branch-free logic form
``x4 = (A xor B) or C`` (Eqn 9).

On TPU we take this one step further ("integer-threshold strengthening",
DESIGN.md §3.4).  The binary-conv pre-activation is x1 = K - 2*cnt where cnt
is the xor-popcount, so the float comparison against xi becomes an *integer*
comparison against a per-channel threshold t on cnt itself:

    gamma > 0:  x4 = 1  iff  x1 >= xi  iff  cnt <= floor((K - xi)/2)
    gamma < 0:  x4 = 1  iff  x1 <= xi  iff  cnt >= ceil((K - xi)/2)

Precomputing (t, s) with s = [gamma < 0] gives the runtime epilogue

    x4 = (cnt <= t) xor s

two integer VPU ops, no float math, no divergence — Eqn (9) in its
TPU-native form.  The equality cases match Eqn (8) exactly (x1 == xi maps to
x4 = 1 for either sign of gamma).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class IntegratedParams(NamedTuple):
    """Offline-folded parameters of one integrated conv+BN+sign layer."""
    threshold: jnp.ndarray  # (O,) int32 — compare against popcount
    sign_flip: jnp.ndarray  # (O,) bool  — xor after the compare ([gamma < 0])


def fold_bn(k_valid: int | jnp.ndarray,
            gamma: jnp.ndarray, beta: jnp.ndarray,
            mu: jnp.ndarray, sigma: jnp.ndarray,
            bias: jnp.ndarray | float = 0.0) -> IntegratedParams:
    """Fold BN(+bias) into an integer popcount threshold (offline, Eqn 6).

    k_valid: number of valid bits per output (K = KH*KW*C_in), scalar or (O,).
    sigma: sqrt(running_var + eps) — the paper's sigma.
    """
    xi = mu - beta * sigma / gamma - bias                       # Eqn 6
    half = (jnp.asarray(k_valid, jnp.float32) - xi) / 2.0
    t_pos = jnp.floor(half)                                     # gamma > 0
    t_neg = jnp.ceil(half) - 1.0                                # gamma < 0
    s = gamma < 0
    t = jnp.where(s, t_neg, t_pos)
    return IntegratedParams(t.astype(jnp.int32), s)


def fold_bn_first_layer(k_valid: int, w_sum: jnp.ndarray,
                        gamma: jnp.ndarray, beta: jnp.ndarray,
                        mu: jnp.ndarray, sigma: jnp.ndarray,
                        bias: jnp.ndarray | float = 0.0) -> IntegratedParams:
    """Fold BN into a threshold on the *bit-plane-weighted* popcount (Eqn 2).

    The first layer consumes 8-bit inputs split into bit-planes I_n in {0,1}.
    With b in {0,1} and w in {-1,+1}:  b.w = ((2b-1).w + sum(w)) / 2, so
        dot_n = (K - 2*cnt_n + w_sum) / 2
        s     = sum_n 2^(n-1) dot_n = 255*(K + w_sum)/2 - wcnt,
        wcnt  = sum_n 2^(n-1) cnt_n   (the weighted popcount the kernel emits)
    (K + w_sum is always even, so the constant is an exact integer.)
    Thresholding s >= xi then becomes wcnt <= C1 - xi with
    C1 = 255*(K + w_sum)/2, handled with the same floor/ceil split as fold_bn.

    w_sum: (O,) sum of the +-1 weights of each filter (2*popcount(w) - K).
    """
    xi = mu - beta * sigma / gamma - bias
    c1 = 255.0 * (jnp.asarray(k_valid, jnp.float32) + w_sum.astype(jnp.float32)) / 2.0
    lim = c1 - xi
    t_pos = jnp.floor(lim)        # gamma > 0: bit = wcnt <= t_pos
    t_neg = jnp.ceil(lim) - 1.0   # gamma < 0: bit = wcnt >= ceil(lim)
    s = gamma < 0
    t = jnp.where(s, t_neg, t_pos)
    return IntegratedParams(t.astype(jnp.int32), s)


def apply_threshold(cnt: jnp.ndarray, p: IntegratedParams) -> jnp.ndarray:
    """Runtime epilogue: {0,1} bits, x4 = (cnt <= t) xor s  (Eqn 9, int form)."""
    return (jnp.less_equal(cnt, p.threshold) ^ p.sign_flip).astype(jnp.int32)


def bn_reference(x1: jnp.ndarray, gamma, beta, mu, sigma, bias=0.0) -> jnp.ndarray:
    """Float oracle of Eqns (3)-(7): binarize(BN(x1 + bias)) in {0,1}."""
    x3 = gamma * ((x1 + bias) - mu) / sigma + beta
    return (x3 >= 0).astype(jnp.int32)
