"""Offline model transform (paper Fig 2): trained params -> packed engine.

Takes the latent float parameters of a trained BNN (``bnn_model.init_params``
format) and produces the compressed PhoneBit artifact:

* binary conv/dense weights bit-packed along the channel dim (C2),
* BN folded into integer popcount thresholds (C4, Eqns 5-9),
* first-layer bit-plane word weights + w_sum constants (C8, Eqn 2),
* the final full-precision layer kept in float (paper Fig 5, conv9).

Also provides ``save_artifact``/``load_artifact`` (.npz) — the "compressed
PhoneBit format" that gets shipped to the device — and ``model_bytes`` for
the Tab-II model-size comparison.
"""

from __future__ import annotations

import io
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import bitplanes, binary_conv, layer_integration, packing
from repro.core.bnn_model import (BConv, BDense, FloatConv, FloatDense,
                                  LayerSpec, Pool, _BN_EPS)


def _sigma(var):
    return jnp.sqrt(var + _BN_EPS)


def convert(params: Sequence[dict], spec: Sequence[LayerSpec],
            input_hw: tuple[int, int]) -> list[dict]:
    """Fold + pack trained float params into the deployable packed pytree."""
    packed: list[dict] = []
    h, w = input_hw
    c = None  # current channel count; None until the first conv sets it
    flat_d = None  # set once the activation is flattened (after BDense)

    for layer, p in zip(spec, params):
        if isinstance(layer, BConv):
            if layer.first:
                cw = packing.num_words(layer.c_in)
                wp = packing.pack_signs(p["w"], axis=2)            # KH,KW,Cw,O
                wp = jnp.repeat(wp[:, :, None, :, :], bitplanes.NUM_PLANES,
                                axis=2)                            # KH,KW,8,Cw,O
                wp = jnp.transpose(wp, (4, 0, 1, 2, 3)).reshape(
                    layer.c_out, -1)                               # O, K*8*Cw
                word_weights = jnp.tile(bitplanes.plane_word_weights(cw),
                                        layer.kernel * layer.kernel)
                wb = jnp.where(p["w"] >= 0, 1.0, -1.0)
                w_sum = jnp.sum(wb, axis=(0, 1, 2))                # (O,)
                thresh = layer_integration.fold_bn_first_layer(
                    layer.k_valid, w_sum, p["gamma"], p["beta"], p["mu"],
                    _sigma(p["var"]), bias=p.get("b", 0.0))
                packed.append(dict(w_packed=wp, word_weights=word_weights,
                                   thresh=thresh))
            else:
                wp = binary_conv.pack_conv_weights(p["w"])
                thresh = layer_integration.fold_bn(
                    layer.k_valid, p["gamma"], p["beta"], p["mu"],
                    _sigma(p["var"]), bias=p.get("b", 0.0))
                packed.append(dict(w_packed=wp, thresh=thresh))
            h = binary_conv.conv_out_size(h, layer.kernel, layer.stride,
                                          layer.pad)
            w = binary_conv.conv_out_size(w, layer.kernel, layer.stride,
                                          layer.pad)
            c = layer.c_out
        elif isinstance(layer, Pool):
            h = (h + sum(layer.pad) - layer.window) // layer.stride + 1
            w = (w + sum(layer.pad) - layer.window) // layer.stride + 1
            packed.append({})
        elif isinstance(layer, BDense):
            if flat_d is None:
                # Flattening a spatial map: pack per position to match the
                # engine's flatten of (N, H, W, Cw) words.
                assert h * w * c == layer.d_in, (
                    f"BDense d_in={layer.d_in} != {h}x{w}x{c}")
                w4 = p["w"].reshape(h, w, c, layer.d_out)
                wp = binary_conv.pack_conv_weights(w4)             # O, H*W*Cw
            else:
                assert flat_d == layer.d_in
                wp = packing.pack_signs(p["w"], axis=0)            # Dw, O
                wp = jnp.transpose(wp, (1, 0))                     # O, Dw
            thresh = layer_integration.fold_bn(
                layer.d_in, p["gamma"], p["beta"], p["mu"],
                _sigma(p["var"]), bias=p.get("b", 0.0))
            packed.append(dict(w_packed=wp, thresh=thresh))
            flat_d = layer.d_out
            c = layer.d_out
        elif isinstance(layer, FloatDense):
            c_per_pos = flat_d if flat_d is not None else c
            if flat_d is None:
                assert h * w * c == layer.d_in
            packed.append(dict(w=p["w"].astype(jnp.float32),
                               b=p["b"].astype(jnp.float32),
                               c_per_pos=c_per_pos))
        elif isinstance(layer, FloatConv):
            assert c == layer.c_in, (c, layer.c_in)
            packed.append(dict(w=p["w"].astype(jnp.float32),
                               b=p["b"].astype(jnp.float32),
                               c_per_pos=c))
            h = binary_conv.conv_out_size(h, layer.kernel, layer.stride,
                                          layer.pad)
            w = binary_conv.conv_out_size(w, layer.kernel, layer.stride,
                                          layer.pad)
            c = layer.c_out
        else:
            packed.append({})
    return packed


def to_graph(packed: Sequence[dict], spec: Sequence[LayerSpec],
             input_hw: tuple[int, int]):
    """Lower a converted artifact to the runtime operator graph.

    Hook into :mod:`repro.runtime` (DESIGN.md §4.2): the graph is the
    deployable form the executor/memory-planner consume; this is what
    ``PhoneBitEngine`` runs through.  Imported lazily to keep ``core`` free
    of a runtime dependency.
    """
    from repro.runtime import lower_packed
    return lower_packed(spec, packed, input_hw)


# --------------------------------------------------------------------------
# Serialized artifact ("compressed PhoneBit format")
# --------------------------------------------------------------------------

def save_artifact(path: str, packed: Sequence[dict]) -> None:
    flat: dict[str, np.ndarray] = {}
    for i, layer in enumerate(packed):
        for k, v in layer.items():
            if isinstance(v, layer_integration.IntegratedParams):
                flat[f"{i}.{k}.threshold"] = np.asarray(v.threshold)
                flat[f"{i}.{k}.sign_flip"] = np.asarray(v.sign_flip)
            else:
                flat[f"{i}.{k}"] = np.asarray(v)
    np.savez_compressed(path, **flat)


def load_artifact(path: str) -> list[dict]:
    data = np.load(path)
    n_layers = 1 + max(int(k.split(".")[0]) for k in data.files)
    packed: list[dict] = [dict() for _ in range(n_layers)]
    pending: dict[tuple[int, str], dict] = {}
    for k in data.files:
        parts = k.split(".")
        i = int(parts[0])
        if len(parts) == 3:  # IntegratedParams field
            pending.setdefault((i, parts[1]), {})[parts[2]] = jnp.asarray(data[k])
        else:
            packed[i][parts[1]] = jnp.asarray(data[k])
    for (i, name), fields in pending.items():
        packed[i][name] = layer_integration.IntegratedParams(
            fields["threshold"], fields["sign_flip"])
    return packed


def model_bytes(packed: Sequence[dict]) -> int:
    """Size of the deployable packed model (Tab II 'BNN' column)."""
    total = 0
    for layer in packed:
        for k, v in layer.items():
            if isinstance(v, layer_integration.IntegratedParams):
                total += v.threshold.size * 4 + v.sign_flip.size  # bool = 1B
            elif k not in ("word_weights", "c_per_pos"):
                # word weights / layout metadata are code, not model
                total += np.asarray(v).size * np.asarray(v).dtype.itemsize
    return total


def float_model_bytes(params: Sequence[dict]) -> int:
    """Size of the full-precision counterpart (Tab II 'CNN' column, fp32)."""
    total = 0
    for layer in params:
        for v in layer.values():
            total += np.asarray(v).size * 4
    return total
