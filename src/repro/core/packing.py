"""Channel compression (paper §V-A): bit-packing along the channel dimension.

PhoneBit packs binary activations/weights along the channel dimension of an
NHWC tensor so that the packed words are minor-most (contiguous) in memory —
the "locality-friendly data layout".  On TPU the natural word is ``int32``
(one VPU lane element); a 128-lane VREG row then holds 4096 binary channels.

Encoding convention (used consistently across the whole framework):
    bit 1  <->  +1
    bit 0  <->  -1
Packing is LSB-first within each 32-bit word.  Channels that do not fill the
last word are padded with 0-bits in *both* operands of any xor-popcount, so
they contribute nothing to the popcount and the valid-length correction
``dot = K_valid - 2*cnt`` stays exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD_BITS = 32


def num_words(channels: int) -> int:
    """Number of int32 words needed to hold ``channels`` bits."""
    return -(-channels // WORD_BITS)


def _bit_weights() -> jnp.ndarray:
    return jnp.left_shift(
        jnp.uint32(1), jnp.arange(WORD_BITS, dtype=jnp.uint32)
    )


def pack_bits(bits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Pack an array of {0,1} values into int32 words along ``axis``.

    ``bits`` may be bool or any integer/float dtype containing 0/1 values.
    Returns an int32 array whose ``axis`` dim is ``num_words(C)``.
    """
    bits = jnp.asarray(bits)
    axis = axis % bits.ndim
    c = bits.shape[axis]
    w = num_words(c)
    pad = w * WORD_BITS - c
    if pad:
        cfg = [(0, 0)] * bits.ndim
        cfg[axis] = (0, pad)
        bits = jnp.pad(bits, cfg)
    bits = jnp.moveaxis(bits, axis, -1)
    bits = bits.reshape(bits.shape[:-1] + (w, WORD_BITS)).astype(jnp.uint32)
    words = jnp.sum(bits * _bit_weights(), axis=-1, dtype=jnp.uint32)
    words = jax.lax.bitcast_convert_type(words, jnp.int32)
    return jnp.moveaxis(words, -1, axis)


def unpack_bits(words: jnp.ndarray, channels: int, axis: int = -1) -> jnp.ndarray:
    """Inverse of :func:`pack_bits`; returns int32 {0,1} array."""
    words = jnp.moveaxis(jnp.asarray(words), axis % words.ndim, -1)
    u = jax.lax.bitcast_convert_type(words, jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (u[..., :, None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(bits.shape[:-2] + (bits.shape[-2] * WORD_BITS,))
    bits = bits[..., :channels].astype(jnp.int32)
    return jnp.moveaxis(bits, -1, axis % (bits.ndim))


def pack_signs(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Binarize a float array by sign (>= 0 -> bit 1) and pack along ``axis``."""
    return pack_bits((x >= 0), axis=axis)


def unpack_to_pm1(words: jnp.ndarray, channels: int, axis: int = -1,
                  dtype: jnp.dtype = jnp.bfloat16) -> jnp.ndarray:
    """Unpack words to a +-1-valued array of ``dtype`` (for MXU / float paths)."""
    bits = unpack_bits(words, channels, axis=axis)
    return (2 * bits - 1).astype(dtype)


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Number of set bits per int32 word (int32 result)."""
    return jax.lax.population_count(words)
