"""Sign binarization + straight-through estimator (training substrate).

The paper is inference-only; training binarized networks (to produce the
models the engine serves) follows Courbariaux et al. [3]: forward pass uses
sign(x) in {-1, +1}, backward pass passes gradients through where |x| <= 1
(the "hard tanh" STE).  Latent weights stay float and are clipped to [-1, 1].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def ste_sign(x: jnp.ndarray) -> jnp.ndarray:
    """sign(x) in {-1, +1} with straight-through gradient (|x| <= 1 window)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _ste_fwd(x):
    return ste_sign(x), x


def _ste_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_fwd, _ste_bwd)


def clip_latent(w: jnp.ndarray) -> jnp.ndarray:
    """Clip latent float weights to [-1, 1] after each optimizer step."""
    return jnp.clip(w, -1.0, 1.0)


def binarize01(x: jnp.ndarray) -> jnp.ndarray:
    """{0,1}-bit view of sign(x) (bit 1 <-> +1), int32."""
    return (x >= 0).astype(jnp.int32)
