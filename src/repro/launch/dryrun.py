import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_DRYRUN_F32"] = "1"   # see models.layers.COMPUTE_DTYPE

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input shape) cell for the
production meshes — 16×16 single-pod and 2×16×16 two-pod — and records
memory / cost / collective analysis per cell.  The two lines above MUST
precede any other import: jax locks the device count at first init, and the
512 placeholder host devices exist only in dry-run processes (tests and
benchmarks see 1 device).

Usage:
    python -m repro.launch.dryrun --arch qwen3-moe-30b-a3b --shape train_4k
    python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    python -m repro.launch.dryrun --all [--jobs 4] [--out artifacts/dryrun]
    python -m repro.launch.dryrun --report [--out artifacts/dryrun]

``--all`` fans cells out to subprocesses (compiles are independent and
XLA's SPMD partitioner is single-threaded per module), caches per-cell
JSON, and prints the aggregate table.  ``--report`` re-prints the table
from cached JSON.
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor


def _cell_id(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    base = f"{arch}__{shape}__{'2x16x16' if multi_pod else '16x16'}"
    return f"{base}__{tag}" if tag else base


def _parse_overrides(pairs):
    import ast
    out = {}
    for p in pairs or ():
        k, v = p.split("=", 1)
        try:
            out[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            out[k] = v
    return out


def run_one(arch: str, shape: str, multi_pod: bool, out_dir: str,
            overrides: dict | None = None, tag: str = "") -> dict:
    """Lower + compile one cell in THIS process; returns the report dict.

    Cost-accounting protocol: XLA counts while-loop bodies once, so
    scan-over-layers models compile two shallow probes (L=1, L=2) whose
    delta is one layer's exact cost, extrapolated to full depth.  Vision
    CNNs recompile with ``unroll=True`` instead (exact single compile).
    The full-depth scanned module is ALWAYS compiled too — that is the
    lowering proof and the source of the memory analysis.
    """
    import dataclasses as _dc
    import jax
    from repro.launch import analysis, cells
    from repro.launch.mesh import make_production_mesh
    from repro.distributed.sharding import rules_for_mesh
    from repro.models.transformer import LMConfig
    from repro.models.dit import DiTConfig
    from repro.models.vit import ViTConfig

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for_mesh(mesh)
    t0 = time.monotonic()
    try:
        build = cells.build_cell(arch, shape, rules, overrides=overrides)
    except cells.SkippedCell as e:
        rep = dict(arch=arch, shape=shape, skipped=True, reason=str(e),
                   mesh="2x16x16" if multi_pod else "16x16")
        _save(out_dir, arch, shape, multi_pod, rep, tag)
        print(f"SKIP {arch} {shape}: {e}")
        return rep

    with mesh:
        lowered = build.lower()
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        print(compiled.memory_analysis())     # proves it fits
        print({k: v for k, v in compiled.cost_analysis().items()
               if "flops" in k or k == "bytes accessed"})

        metrics = None
        cfg = build.cfg
        if isinstance(cfg, (LMConfig, DiTConfig, ViTConfig)):
            # Unrolled shallow probes: L=1 / L=2 with python-loop layers;
            # the delta is one layer's exact cost (incl. remat recompute
            # and per-layer collectives), extrapolated to full depth.
            probes = []
            for l in (1, 2):
                pb = cells.build_cell(
                    arch, shape, rules,
                    overrides=dict(overrides or {}, n_layers=l,
                                   unroll=True))
                probes.append(analysis.collect(pb.lower().compile(),
                                               mesh.size))
            metrics = analysis.extrapolate(probes[0], probes[1],
                                           cfg.n_layers)
        else:  # vision CNNs: exact unrolled compile
            ub = cells.build_cell(arch, shape, rules,
                                  overrides=dict(overrides or {},
                                                 unroll=True))
            metrics = analysis.collect(ub.lower().compile(), mesh.size)

    report = analysis.analyze(
        arch, shape, build.kind, mesh, compiled,
        model_flops=analysis.model_flops_for(build), metrics=metrics,
        note=build.note)
    rep = report.to_json()
    rep.update(skipped=False, t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1),
               t_total_s=round(time.monotonic() - t0, 1),
               overrides=overrides or {}, tag=tag)
    _save(out_dir, arch, shape, multi_pod, rep, tag)
    print(f"OK {arch} {shape} mesh={rep['mesh']} "
          f"bottleneck={rep['bottleneck']} "
          f"t=(c {rep['t_compute']:.4f}s, m {rep['t_memory']:.4f}s, "
          f"n {rep['t_collective']:.4f}s) "
          f"roofline={rep['roofline_fraction']:.3f} "
          f"[lower {t_lower:.0f}s compile {t_compile:.0f}s]")
    return rep


def _save(out_dir, arch, shape, multi_pod, rep, tag: str = ""):
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    (p / (_cell_id(arch, shape, multi_pod, tag) + ".json")).write_text(
        json.dumps(rep, indent=2))


def run_all(out_dir: str, jobs: int, multi_pod_also: bool = True,
            force: bool = False, timeout: int = 3600) -> None:
    """Fan out every cell to subprocesses with caching."""
    from repro import configs

    work = []
    for arch, shape in configs.all_cells():
        meshes = [False, True] if multi_pod_also else [False]
        for mp in meshes:
            cache = pathlib.Path(out_dir) / (
                _cell_id(arch, shape.name, mp) + ".json")
            if cache.exists() and not force:
                continue
            work.append((arch, shape.name, mp))

    def launch(item):
        arch, shape, mp = item
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", out_dir]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.monotonic()
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout)
        dt = time.monotonic() - t0
        tag = _cell_id(arch, shape, mp)
        if r.returncode != 0:
            err = (r.stderr or r.stdout).strip().splitlines()
            _save(out_dir, arch, shape, mp,
                  dict(arch=arch, shape=shape, skipped=False, failed=True,
                       mesh="2x16x16" if mp else "16x16",
                       error="\n".join(err[-15:])))
            return f"FAIL {tag} ({dt:.0f}s)"
        return f"done {tag} ({dt:.0f}s)"

    print(f"{len(work)} cells to compile, {jobs} parallel jobs")
    with ThreadPoolExecutor(max_workers=jobs) as ex:
        for msg in ex.map(launch, work):
            print(msg, flush=True)
    print_table(out_dir)


def print_table(out_dir: str) -> None:
    rows = []
    for f in sorted(pathlib.Path(out_dir).glob("*.json")):
        rows.append(json.loads(f.read_text()))
    if not rows:
        print("no cached reports in", out_dir)
        return
    hdr = (f"{'arch':24} {'shape':12} {'mesh':8} {'kind':8} "
           f"{'bottleneck':10} {'t_comp':>9} {'t_mem':>9} {'t_coll':>9} "
           f"{'roofline':>8} {'useful':>7} {'peakGB':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("skipped"):
            print(f"{r['arch']:24} {r['shape']:12} {r.get('mesh', ''):8} "
                  f"SKIP     ({r.get('reason', '')[:60]})")
            continue
        if r.get("failed"):
            print(f"{r['arch']:24} {r['shape']:12} {r.get('mesh', ''):8} "
                  f"FAILED   {r.get('error', '').splitlines()[-1][:70]}")
            continue
        print(f"{r['arch']:24} {r['shape']:12} {r['mesh']:8} "
              f"{r['kind']:8} {r['bottleneck']:10} "
              f"{r['t_compute']:9.4f} {r['t_memory']:9.4f} "
              f"{r['t_collective']:9.4f} {r['roofline_fraction']:8.3f} "
              f"{r['useful_flops_ratio']:7.3f} "
              f"{r['peak_memory_bytes'] / 2**30:7.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--set", action="append", dest="overrides",
                    metavar="KEY=VALUE",
                    help="config override (hillclimb variants), repeatable")
    ap.add_argument("--tag", default="",
                    help="suffix for the report file (variants don't "
                         "clobber the baseline)")
    args = ap.parse_args()

    if args.report:
        print_table(args.out)
    elif args.all:
        run_all(args.out, args.jobs,
                multi_pod_also=not args.single_pod_only, force=args.force)
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required (or --all/--report)")
        run_one(args.arch, args.shape, args.multi_pod, args.out,
                overrides=_parse_overrides(args.overrides), tag=args.tag)


if __name__ == "__main__":
    main()
