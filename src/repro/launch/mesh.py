"""Production meshes.

Functions, not module-level constants: importing this module never touches
jax device state (device count is locked at first jax init, and only the
dry-run process sets the 512-host-device XLA flag).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
    _HAVE_AXIS_TYPE = True
except ImportError:  # older jax: meshes are implicitly "auto" on every axis
    AxisType = None
    _HAVE_AXIS_TYPE = False


def _mesh(shape, axes):
    if _HAVE_AXIS_TYPE:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips).

    ``pod`` is the slow-interconnect axis (DP replicas by default; the
    pipeline schedule in distributed.pipeline can claim it instead).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))
