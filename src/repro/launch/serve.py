"""Serving drivers.

Two modes, matching the paper's engine and the LM serving path:

* ``--mode bnn``  — PhoneBit engine (Fig 2/3): train-or-init a paper
  network, convert offline, serve batched uint8 images through the
  BatchScheduler, report latency/throughput.
* ``--mode lm``   — continuous-batching decode: prefill prompts into KV
  slots, decode ticks across all active sequences.

    PYTHONPATH=src python -m repro.launch.serve --mode bnn \
        --network yolov2-tiny --requests 32
    PYTHONPATH=src python -m repro.launch.serve --mode lm --requests 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import paper_nets, transformer
from repro.serving import BatchScheduler, PhoneBitEngine
from repro.serving.lm_server import LMServer


def serve_bnn(args) -> dict:
    spec, (h, w, c), params = paper_nets.init(args.network)
    engine = PhoneBitEngine.from_trained(params, spec, (h, w),
                                         matmul_mode="xla")
    print(f"{args.network}: packed model {engine.model_bytes / 2**20:.1f} "
          f"MiB")
    sched = BatchScheduler(max_batch=args.batch, max_wait_s=0.0,
                           buckets=(1, 2, 4, 8, 16))
    rng = np.random.default_rng(0)

    def run(payloads):
        x = jnp.asarray(np.stack(payloads))
        out = engine(x)
        return list(np.asarray(out))

    # warmup compile per bucket used
    _ = run([rng.integers(0, 256, (h, w, c), dtype=np.uint8)]
            * sched.bucket_for(min(args.batch, args.requests)))

    t0 = time.monotonic()
    done = 0
    for i in range(args.requests):
        sched.submit(rng.integers(0, 256, (h, w, c), dtype=np.uint8))
    while len(sched):
        done += len(sched.drain(run))
    dt = time.monotonic() - t0
    print(f"served {done} requests in {dt:.2f}s "
          f"({done / dt:.1f} img/s, {dt / done * 1e3:.1f} ms/img)")
    return {"requests": done, "throughput": done / dt}


def serve_lm(args) -> dict:
    cfg = transformer.LMConfig(
        name="lm-serve-demo", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_head=32, d_ff=512, vocab=1024,
        tie_embeddings=True)
    mesh = make_host_mesh(data=1, model=len(jax.devices()))
    rules = rules_for_mesh(mesh)
    with mesh:
        params = jax.jit(
            lambda k: transformer.init_params(k, cfg, ep=rules.tp,
                                              vocab_pad_to=rules.tp),
            out_shardings=rules.tree_shardings(
                transformer.param_specs(cfg, rules)))(jax.random.key(0))
        server = LMServer(cfg=cfg, rules=rules, params=params,
                          n_slots=args.batch, max_seq=args.max_seq)
        rng = np.random.default_rng(0)
        t0 = time.monotonic()
        outs = []
        for i in range(args.requests):
            prompt = list(rng.integers(1, cfg.vocab, size=8))
            outs.append(server.generate(prompt, max_new=args.max_new))
        dt = time.monotonic() - t0
        toks = sum(len(o) for o in outs)
        print(f"generated {toks} tokens for {args.requests} prompts in "
              f"{dt:.2f}s ({toks / dt:.1f} tok/s)")
        return {"tokens": toks, "tok_per_s": toks / dt}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("bnn", "lm"), default="bnn")
    ap.add_argument("--network", default="yolov2-tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)
    if args.mode == "bnn":
        return serve_bnn(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
