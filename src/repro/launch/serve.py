"""Serving drivers on the production serving subsystem (DESIGN.md §7).

Both modes front their engine with the shared server protocol
(submit/poll/drain + metrics):

* ``--mode bnn``  — PhoneBit engine (Fig 2/3) behind an
  :class:`~repro.serving.server.InferenceServer`: per-bucket precompiled
  executables (no manual warm-up), async double-buffered dispatch
  (``--sync`` for the blocking baseline), optional data-parallel batch
  sharding over the host devices (``--shard``).
* ``--workload``  — serve a registered end-to-end workload
  (``repro.workloads``): arbitrary-size images go through the workload's
  preprocess hook, and the server scatters *decoded* predictions (top-k
  labels / NMS'd boxes) instead of raw logits.
* ``--mode lm``   — continuous-batching decode through the LMServer's
  identical submit/drain surface.
* ``--export-artifact PATH`` / ``--artifact PATH`` — the zero-warmup
  pair (DESIGN.md §12): export AOT bucket executables offline, then
  boot the server from them with zero serve-time traces.
* ``--workloads a,b,c`` — multi-tenant serving: each entry
  (``name[:weight]``) becomes a weighted-fair lane behind one
  :class:`~repro.serving.multiplex.MultiTenantServer`.
* ``--journal PATH`` — durable request journal (DESIGN.md §14.3):
  accepted submits are WAL-journaled before they enqueue, and a boot
  over an existing journal replays whatever a crashed predecessor left
  unresolved.

    PYTHONPATH=src python -m repro.launch.serve --mode bnn \
        --network yolov2-tiny --requests 32
    PYTHONPATH=src python -m repro.launch.serve --mode bnn \
        --workload yolov2_tiny_voc --input-hw 64 --requests 8
    PYTHONPATH=src python -m repro.launch.serve \
        --workload alexnet_imagenet --export-artifact /tmp/alex.art
    PYTHONPATH=src python -m repro.launch.serve \
        --workload alexnet_imagenet --artifact /tmp/alex.art
    PYTHONPATH=src python -m repro.launch.serve \
        --workloads alexnet_imagenet:3,vgg16_imagenet --requests 8
    PYTHONPATH=src python -m repro.launch.serve --mode lm --requests 4
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import paper_nets, transformer
from repro.obs import trace as obs_trace
from repro.serving import InferenceServer, PhoneBitEngine, buckets_for
from repro.serving.lm_server import LMServer


def _print_metrics(tag: str, m: dict) -> None:
    lat = (f"p50 {m['p50_ms']:.1f} ms, p95 {m['p95_ms']:.1f} ms"
           if m.get("p50_ms") is not None else "no latency samples")
    thr = (f"{m['throughput']:.1f}/s" if m.get("throughput") else "n/a")
    print(f"[{tag}] served {m['served']} (dropped {m['dropped']}), "
          f"{lat}, throughput {thr}")
    # Resilience counters (DESIGN.md §11) — only noisy when nonzero.
    res = {k: m[k] for k in ("retries", "errors", "rejected", "degraded")
           if m.get(k)}
    if res:
        mode = f", mode {m['mode']}" if m.get("mode") else ""
        print(f"[{tag}] resilience: "
              + ", ".join(f"{k} {v}" for k, v in res.items()) + mode)


def serve_bnn(args) -> dict:
    workload = None
    if args.workload:
        from repro import workloads

        workload = workloads.get(args.workload,
                                 variant=args.variant,
                                 matmul_mode=args.matmul_mode,
                                 input_hw=args.input_hw or None)
        engine, (h, w) = workload.engine, workload.input_hw
        print(f"{workload.name}: packed model "
              f"{workload.model_bytes / 2**20:.1f} MiB, input {h}x{w}, "
              f"task {workload.task}")
    else:
        spec, (h, w, c), params = paper_nets.init(args.network)
        if args.input_hw:      # fully-conv nets serve any resolution
            h = w = args.input_hw
        engine = PhoneBitEngine.from_trained(params, spec, (h, w),
                                             matmul_mode=args.matmul_mode)
        print(f"{args.network}: packed model "
              f"{engine.model_bytes / 2**20:.1f} MiB, input {h}x{w}")
    if args.export_artifact:
        # Offline half of zero-warmup serving: write the AOT bucket
        # executables + autotune table and exit.
        meta = engine.export_artifact(
            args.export_artifact, buckets_for(args.batch),
            **({"workload": workload.name} if workload else {}))
        print(f"[bnn] exported artifact {args.export_artifact} "
              f"(buckets {sorted(int(b) for b in meta['buckets'])}, "
              f"mode {meta['mode']})")
        return meta

    mesh = None
    if args.shard and len(jax.devices()) > 1:
        mesh = make_host_mesh(data=len(jax.devices()), model=1)
    journal = None
    if args.journal:
        from repro.serving.recovery import RequestJournal, replay_journal

        journal = RequestJournal(args.journal)
    server = InferenceServer(
        engine, max_batch=args.batch, max_wait_s=0.0,
        buckets=buckets_for(args.batch),
        async_dispatch=not args.sync, mesh=mesh,
        preprocess=workload.preprocess_hook if workload else None,
        max_queue=args.max_queue or None,
        watchdog_s=args.watchdog_s,
        artifact=args.artifact,
        journal=journal)
    if journal is not None:
        # Crash recovery (DESIGN.md §14.3): requests journaled by a
        # previous process but never resolved are resubmitted first.
        replayed = replay_journal(server, args.journal)
        if replayed:
            print(f"[bnn] journal {args.journal}: replaying "
                  f"{len(replayed)} unresolved request(s)")
    if args.artifact:
        rep = server.artifact_report
        print(f"[bnn] artifact {args.artifact}: loaded buckets "
              f"{rep['loaded']}, missed {dict(rep['missed'])}")
    else:
        compile_s = server.compile_buckets()
        print(f"compiled buckets {list(compile_s)} in "
              f"{sum(compile_s.values()):.2f}s")

    plan = None
    if args.fault_storm:
        # Demo the resilience layer end to end: seeded transient device
        # faults + latency spikes while the request stream flows.
        from repro.serving.faults import FaultPlan, FaultSpec, install

        plan = install(FaultPlan([
            FaultSpec("server.device", "device_fault", times=2),
            FaultSpec("server.device", "device_fault", rate=0.1, after=2),
            FaultSpec("server.device", "latency_spike", rate=0.1,
                      duration_s=0.002),
        ], seed=7))
        print("[bnn] fault storm installed (seed 7)")

    rng = np.random.default_rng(0)
    # Workload requests arrive at an off-network size to exercise the
    # preprocess hook; raw-engine requests arrive network-sized.
    req_hw = (h + h // 2, w * 2) if workload else (h, w)
    reqs = []
    for _ in range(args.requests):
        reqs.append(server.submit(
            rng.integers(0, 256, (*req_hw, 3), dtype=np.uint8),
            deadline_s=args.deadline_s))
    server.drain()
    if plan is not None:
        from repro.serving import faults

        faults.uninstall()
        print(f"[bnn] storm: {len(plan.log)} faults injected, "
              f"{len(server.health.demotions)} demotions")
    m = server.metrics()
    _print_metrics("bnn", m)
    if args.artifact:
        print(f"[bnn] serve-time traces: {engine.trace_count}")
    if workload is not None:
        first = next((r for r in reqs if r.result is not None), None)
        if first is not None:
            preds = workload.format(first.result)
            print(f"[bnn] request 0 -> {len(preds)} predictions; "
                  f"top: {preds[:3]}")
    assert sum(r.done for r in reqs) >= args.requests
    return m


def serve_multi(args) -> dict:
    """Multi-tenant serving: each ``--workloads`` entry (name[:weight])
    is a weighted-fair lane behind one MultiTenantServer."""
    from repro import workloads
    from repro.serving import MultiTenantServer

    mux = MultiTenantServer(max_batch=args.batch, max_wait_s=0.0,
                            buckets=buckets_for(args.batch),
                            max_queue=args.max_queue or None,
                            watchdog_s=args.watchdog_s)
    wls = {}
    for entry in args.workloads.split(","):
        name, _, w = entry.strip().partition(":")
        weight = float(w) if w else 1.0
        wl = workloads.get(name, variant=args.variant,
                           matmul_mode=args.matmul_mode,
                           input_hw=args.input_hw or None)
        wls[name] = wl
        mux.add_workload(name, wl, weight=weight)
        print(f"[mux] tenant {name}: weight {weight}, "
              f"input {wl.input_hw[0]}x{wl.input_hw[1]}, task {wl.task}")

    rng = np.random.default_rng(0)
    reqs = {name: [] for name in wls}
    for _ in range(args.requests):
        for name, wl in wls.items():
            h, w = wl.input_hw
            reqs[name].append(mux.submit(
                name,
                rng.integers(0, 256, (h + h // 2, w * 2, 3),
                             dtype=np.uint8),
                deadline_s=args.deadline_s))
    mux.drain()
    m = mux.metrics()
    for name in wls:
        _print_metrics(f"mux:{name}", m["tenants"][name])
    ledger = ", ".join(
        f"{name} {f['dispatched_rows']} rows (w={f['weight']})"
        for name, f in m["fairness"].items())
    print(f"[mux] fairness: {ledger}")
    assert all(r.done for rs in reqs.values() for r in rs)
    return m


def serve_lm(args) -> dict:
    cfg = transformer.LMConfig(
        name="lm-serve-demo", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_head=32, d_ff=512, vocab=1024,
        tie_embeddings=True)
    mesh = make_host_mesh(data=1, model=len(jax.devices()))
    rules = rules_for_mesh(mesh)
    with mesh:
        params = jax.jit(
            lambda k: transformer.init_params(k, cfg, ep=rules.tp,
                                              vocab_pad_to=rules.tp),
            out_shardings=rules.tree_shardings(
                transformer.param_specs(cfg, rules)))(jax.random.key(0))
        server = LMServer(cfg=cfg, rules=rules, params=params,
                          n_slots=args.batch, max_seq=args.max_seq)
        rng = np.random.default_rng(0)
        reqs = [server.submit(list(rng.integers(1, cfg.vocab, size=8)),
                              max_new=args.max_new)
                for _ in range(args.requests)]
        done = server.drain()
        assert all(r.done for r in reqs) and len(done) == len(reqs)
        m = server.metrics()
        toks = sum(len(r.result) for r in reqs if r.result)
        _print_metrics("lm", m)
        print(f"[lm] {toks} tokens, kv utilization "
              f"{m['kv_utilization']:.0%}")
        return m


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("bnn", "lm"), default="bnn")
    ap.add_argument("--network", default="yolov2-tiny")
    ap.add_argument("--workload", default=None,
                    help="serve a registered end-to-end workload "
                         "(repro.workloads: e.g. yolov2_tiny_voc) — "
                         "preprocess hook + decoded predictions")
    ap.add_argument("--workloads", default=None, metavar="A[:W],B[:W]",
                    help="multi-tenant serving: comma-separated "
                         "workload names, each optionally :weighted "
                         "(e.g. alexnet_imagenet:3,vgg16_imagenet) — "
                         "one weighted-fair lane per entry")
    ap.add_argument("--matmul-mode", default="xla")
    ap.add_argument("--variant", default="paper",
                    help="workload variant (paper | tiny)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--input-hw", type=int, default=0,
                    help="override input resolution (fully-conv nets; "
                         "0 = the paper's)")
    ap.add_argument("--sync", action="store_true",
                    help="synchronous dispatch (baseline; default is "
                         "async double-buffered)")
    ap.add_argument("--shard", action="store_true",
                    help="data-parallel batch sharding over host devices")
    ap.add_argument("--deadline-s", type=float, default=None)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission: submits beyond this queue "
                         "depth resolve rejected (0 = unbounded)")
    ap.add_argument("--watchdog-s", type=float, default=None,
                    help="bound each device readback; a stalled "
                         "executable resolves error instead of hanging")
    ap.add_argument("--fault-storm", action="store_true",
                    help="install a seeded fault plan (transient device "
                         "faults + latency spikes) to demo retry/"
                         "degrade — bnn mode only")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--export-artifact", default=None, metavar="PATH",
                    help="export AOT bucket executables + autotune "
                         "table to this directory and exit (the "
                         "offline half of zero-warmup serving)")
    ap.add_argument("--artifact", default=None, metavar="PATH",
                    help="boot the server from an exported artifact: "
                         "executables deserialize instead of tracing "
                         "(zero serve-time compiles)")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="durable request journal (JSONL WAL, DESIGN.md "
                         "§14.3): accepted submits hit disk before they "
                         "enqueue; on boot, unresolved requests from a "
                         "crashed process are replayed — bnn mode only")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record serving-stage spans and write a "
                         "Chrome/Perfetto trace-event JSON here "
                         "(chrome://tracing / ui.perfetto.dev)")
    args = ap.parse_args(argv)
    tracer = obs_trace.install() if args.trace_out else None
    try:
        if args.workloads:
            return serve_multi(args)
        if args.mode == "bnn":
            return serve_bnn(args)
        return serve_lm(args)
    finally:
        if tracer is not None:
            obs_trace.uninstall()
            tracer.export(args.trace_out)
            print(f"wrote {len(tracer.events)} trace events to "
                  f"{args.trace_out}")


if __name__ == "__main__":
    main()
