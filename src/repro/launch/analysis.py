"""Compiled-artifact analysis: cost, memory, collective bytes, roofline.

The dry-run compiles each (arch × shape × mesh) cell to a post-SPMD HLO
module — the per-device program.  From it we derive the three roofline
terms (TPU v5e targets):

    compute    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16)
    memory     = HLO_bytes_per_device / 819 GB/s (HBM)
    collective = wire_bytes_per_device / 50 GB/s (ICI link)

``cost_analysis`` provides FLOPs and bytes; collective bytes are NOT in
cost_analysis, so we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, converting to wire bytes with the standard ring-
algorithm factors (all-reduce moves 2(N-1)/N × payload, gather/scatter
(N-1)/N, permute 1×).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
CHIP_WATTS = 185.0           # ~TDP midpoint, used by the Tab-IV energy model

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\((.*)\)", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[shape] token in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += int(n * _DTYPE_BYTES[dt])
    return total


def parse_collectives(hlo_text: str, default_group: int) -> list[dict]:
    """Per-collective records from post-SPMD HLO text (per-device view)."""
    out = []
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.match(line)
        if not m:
            continue
        out_shape, kind, operands = m.groups()
        if "-done" in stripped.split("(")[0]:
            continue  # the -start op carries the shapes
        gm = _GROUPS_RE.search(line)
        if gm:
            group = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            group = (len(gb.group(1).split(",")) if gb else default_group)
        operand_bytes = shape_bytes(operands)
        out_bytes = shape_bytes(out_shape)
        out.append(dict(kind=kind, operand_bytes=operand_bytes,
                        out_bytes=out_bytes, group=max(group, 1)))
    return out


def wire_bytes(rec: dict) -> float:
    """Per-device wire bytes of one collective (ring-algorithm factors)."""
    n = rec["group"]
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    k = rec["kind"]
    if k == "all-reduce":
        return 2.0 * rec["operand_bytes"] * frac
    if k == "all-gather":
        return rec["out_bytes"] * frac
    if k == "reduce-scatter":
        return rec["operand_bytes"] * frac
    if k == "all-to-all":
        return rec["operand_bytes"] * frac
    if k == "collective-permute":
        return float(rec["operand_bytes"])
    return 0.0


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    kind: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float          # dtype-adjusted (see dtype_factor)
    collective_wire_bytes: float     # dtype-adjusted
    collective_operand_bytes: float
    collective_counts: dict
    peak_memory_bytes: int
    argument_bytes: int
    temp_bytes: int                  # raw (f32-mode activations = 2× bf16)
    output_bytes: int
    model_flops: float          # 6·N_active·tokens (train) / analytic fwd
    # 0.5 when the dry-run compiled in f32 accounting mode: XLA:CPU
    # legalizes bf16 dots to f32 (no native bf16 FMA), so a bf16 model's
    # HLO is riddled with converts and f32 collectives a TPU lowering
    # would not have.  The f32-mode module moves exactly 2× the bytes of
    # the bf16 deployment on every activation/weight path.
    dtype_factor: float = 1.0
    bytes_raw: float = 0.0
    wire_raw: float = 0.0
    note: str = ""

    # ---- roofline -----------------------------------------------------
    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        total_hlo = self.flops_per_device * self.n_devices
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-at-peak time / bound time (the score)."""
        if self.t_bound <= 0:
            return 0.0
        t_model = self.model_flops / (self.n_devices * PEAK_FLOPS)
        return t_model / self.t_bound

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def collect(compiled, n_dev: int) -> dict:
    """Raw per-device metrics of one compiled module.

    NOTE: XLA's HloCostAnalysis counts while-loop bodies ONCE regardless
    of trip count, so for scan-over-layers models these raw numbers cover
    one layer plus the non-scanned prologue/epilogue.  The dry-run
    extrapolates with two probe compiles (L=1, L=2) — see
    :func:`extrapolate`.
    """
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text(), default_group=n_dev)
    counts: dict[str, float] = {}
    for c in colls:
        counts[c["kind"]] = counts.get(c["kind"], 0) + 1
    return dict(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        wire=float(sum(wire_bytes(c) for c in colls)),
        operand=float(sum(c["operand_bytes"] for c in colls)),
        counts=counts,
    )


def extrapolate(m1: dict, m2: dict, n_layers: int) -> dict:
    """metrics(L) = metrics(1) + (L-1)·(metrics(2) - metrics(1)).

    Exact for scan-over-layers models: the L=2/L=1 delta is one layer's
    cost, the L=1 value carries the prologue/epilogue once.
    """
    out = {}
    for k in ("flops", "bytes", "wire", "operand"):
        out[k] = m1[k] + (n_layers - 1) * (m2[k] - m1[k])
    counts = {}
    for kind in set(m1["counts"]) | set(m2["counts"]):
        c1 = m1["counts"].get(kind, 0)
        c2 = m2["counts"].get(kind, 0)
        counts[kind] = c1 + (n_layers - 1) * (c2 - c1)
    out["counts"] = counts
    return out


def analyze(arch: str, shape: str, kind: str, mesh, compiled,
            model_flops: float, metrics: dict | None = None,
            note: str = "") -> CellReport:
    """Build a CellReport.  ``metrics`` overrides the raw collect() of
    ``compiled`` (used when probe-extrapolated numbers are available);
    memory statistics always come from the full-depth ``compiled``."""
    import os
    n_dev = mesh.size
    if metrics is None:
        metrics = collect(compiled, n_dev)
    mem = compiled.memory_analysis()
    factor = 0.5 if os.environ.get("REPRO_DRYRUN_F32") else 1.0
    return CellReport(
        arch=arch, shape=shape, kind=kind,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        n_devices=n_dev,
        flops_per_device=metrics["flops"],
        bytes_per_device=metrics["bytes"] * factor,
        collective_wire_bytes=metrics["wire"] * factor,
        collective_operand_bytes=metrics["operand"],
        collective_counts=metrics["counts"],
        peak_memory_bytes=int(getattr(mem, "peak_memory_in_bytes", 0)),
        argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        model_flops=model_flops, dtype_factor=factor,
        bytes_raw=metrics["bytes"], wire_raw=metrics["wire"], note=note)


# --------------------------------------------------------------------------
# MODEL_FLOPS per cell (analytic "useful work")
# --------------------------------------------------------------------------

def model_flops_for(build) -> float:
    """Analytic useful FLOPs for one step (the roofline numerator).

    Counts matmul work only: per-token layer matmuls (2·params_matmul,
    embeddings/norms excluded), the *ideal* attention FLOPs (causal
    S²/2), and the logits head.  Backward = 2× forward.  HLO FLOPs above
    this ratio are framework waste (remat recompute, masked attention,
    dead expert slots, SPMD padding).
    """
    from repro.models.transformer import LMConfig
    from repro.models.dit import DiTConfig
    from repro.models.vit import ViTConfig
    from repro.models.convnext import ConvNeXtConfig
    from repro.models.efficientnet import EffNetConfig

    cfg, kind = build.cfg, build.kind
    args = build.abstract_args

    if isinstance(cfg, LMConfig):
        d, l = cfg.d_model, cfg.n_layers
        attn_p = d * cfg.qkv_dim + 2 * d * cfg.kv_dim + cfg.qkv_dim * d
        if cfg.moe:
            mlp_p = d * cfg.n_experts + 3 * cfg.top_k * d * cfg.d_ff_expert
        else:
            n_mats = 3 if cfg.mlp_act == "swiglu" else 2
            mlp_p = n_mats * d * cfg.d_ff
        per_tok_fwd = 2.0 * l * (attn_p + mlp_p)
        head_fwd = 2.0 * d * cfg.vocab

        def attn_fwd(b, s_q, s_kv, causal):
            pairs = s_q * s_kv * (0.5 if causal else 1.0)
            return 4.0 * b * cfg.n_heads * cfg.d_head * pairs

        if kind == "train":
            b, s = args[2]["tokens"].shape
            fwd = (b * s * (per_tok_fwd + head_fwd)
                   + l * attn_fwd(b, s, s, True))
            return 3.0 * fwd
        if kind == "prefill":
            b, s = args[1].shape
            return (b * s * per_tok_fwd + b * head_fwd
                    + l * attn_fwd(b, s, s, True))
        if kind == "decode":
            b = args[2].shape[0]
            s_cache = args[1]["k"].shape[3]
            return (b * (per_tok_fwd + head_fwd)
                    + l * attn_fwd(b, 1, s_cache, False))

    if isinstance(cfg, DiTConfig):
        d, l = cfg.d_model, cfg.n_layers
        per_tok_fwd = 2.0 * l * (4 * d * d + 2 * d * cfg.d_ff)
        if kind == "train":
            b = args[2]["latents"].shape[0]
            lat = args[2]["latents"].shape[1]
        else:
            b, lat = args[1].shape[0], args[1].shape[1]
        n_tok = (lat // cfg.patch) ** 2
        cond_fwd = 2.0 * b * l * d * 6 * d          # adaLN projections
        attn = 4.0 * b * l * cfg.n_heads * cfg.d_head * n_tok * n_tok
        fwd = b * n_tok * per_tok_fwd + cond_fwd + attn
        return 3.0 * fwd if kind == "train" else fwd

    if isinstance(cfg, ViTConfig):
        d, l = cfg.d_model, cfg.n_layers
        if kind == "train":
            b, res = (args[2]["images"].shape[0],
                      args[2]["images"].shape[1])
        else:
            b, res = args[1].shape[0], args[1].shape[1]
        n_tok = (res // cfg.patch) ** 2 + 1
        per_tok_fwd = 2.0 * l * (4 * d * d + 2 * d * cfg.d_ff)
        patch_fwd = 2.0 * b * (n_tok - 1) * cfg.patch ** 2 * 3 * d
        attn = 4.0 * b * l * cfg.n_heads * cfg.d_head * n_tok * n_tok
        fwd = b * n_tok * per_tok_fwd + patch_fwd + attn
        return 3.0 * fwd if kind == "train" else fwd

    if isinstance(cfg, ConvNeXtConfig):
        imgs = args[-1]["images"] if kind == "train" else args[-1]
        b, res = imgs.shape[0], imgs.shape[1]
        macs = _convnext_macs(cfg, res)
        return (6.0 if kind == "train" else 2.0) * b * macs

    if isinstance(cfg, EffNetConfig):
        imgs = args[-1]["images"] if kind == "train" else args[-1]
        b, res = imgs.shape[0], imgs.shape[1]
        macs = _effnet_macs(cfg, res)
        return (6.0 if kind == "train" else 2.0) * b * macs
    return 0.0


def model_flops_cell(arch_id: str, shape_name: str) -> float:
    """Mesh-free analytic FLOPs for a cell (patches cached reports)."""
    import types
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models.transformer import LMConfig
    from repro.models.dit import DiTConfig

    rec = configs.get(arch_id)
    shape = rec.shape(shape_name)
    cfg = rec.full
    kind = shape.kind

    def sds(shp):
        return jax.ShapeDtypeStruct(shp, jnp.float32)

    if rec.family == "lm":
        b, s = shape.global_batch, shape.seq_len
        if kind == "train":
            args = (None, None, {"tokens": sds((b, s))})
        elif kind == "prefill":
            args = (None, sds((b, s)))
        else:
            args = ({"k": sds((cfg.n_layers, b, cfg.n_kv_heads, s,
                               cfg.d_head))}, None, sds((b, 1)))
            args = (None, args[0], args[2])
    elif rec.family == "diffusion":
        lat = shape.img_res // cfg.vae_downsample
        x = sds((shape.batch, lat, lat, cfg.latent_channels))
        if kind == "train":
            args = (None, None, {"latents": x})
        else:
            args = (None, x)
    else:
        x = sds((shape.batch, shape.img_res, shape.img_res, 3))
        if kind == "train":
            args = (None, None, None, {"images": x})
        else:
            args = (None, None, x)
    build = types.SimpleNamespace(cfg=cfg, kind=kind, abstract_args=args)
    return model_flops_for(build)


def _convnext_macs(cfg, res: int) -> float:
    """Per-image MACs of the ConvNeXt forward at input res."""
    macs = (res // 4) ** 2 * 4 * 4 * 3 * cfg.dims[0]      # stem
    hw = res // 4
    prev = cfg.dims[0]
    for depth, dim in zip(cfg.depths, cfg.dims):
        if dim != prev:
            hw //= 2
            macs += hw * hw * 2 * 2 * prev * dim           # downsample
        macs += depth * hw * hw * (7 * 7 * dim              # dw conv
                                   + 2 * dim * 4 * dim)     # pw convs
        prev = dim
    macs += cfg.dims[-1] * cfg.n_classes
    return float(macs)


def _effnet_macs(cfg, res: int) -> float:
    """Per-image MACs of the EfficientNet forward at input res."""
    hw = res // 2
    macs = hw * hw * 3 * 3 * 3 * cfg.stem_ch
    for e, k, s, c_in, c_out, r in cfg.stages():
        mid = c_in * e
        for i in range(r):
            cin_i = c_in if i == 0 else c_out
            mid_i = cin_i * e
            if s == 2 and i == 0:
                hw //= 2
            if e != 1:
                macs += hw * hw * cin_i * mid_i            # expand 1x1
            macs += hw * hw * k * k * mid_i                # depthwise
            se = max(1, int(cin_i * cfg.se_ratio))
            macs += 2 * mid_i * se                         # SE
            macs += hw * hw * mid_i * c_out                # project 1x1
    macs += hw * hw * cfg.stages()[-1][4] * cfg.head_ch
    macs += cfg.head_ch * cfg.n_classes
    return float(macs)


def save_report(path: str, report: CellReport) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2)
