"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-3b-a800m \
        --smoke --steps 20 --checkpoint-dir /tmp/ckpt --checkpoint-every 5

Fault-tolerance story (each piece unit-tested in tests/test_system.py):

* **checkpoint/restart** — async atomic checkpoints every N steps; on
  start, the latest checkpoint (params, opt state, step) is restored and
  the data pipeline resumes from the same step (step-indexed batches).
* **elastic re-mesh** — checkpoints store full host arrays; restore
  re-places them with the *current* mesh's shardings, so a restart with a
  different device count (node failure, survivor set) just works.
* **straggler monitor** — EWMA step-time outlier detection; persistent
  stragglers trigger the mitigation hook (here: log + checkpoint, the
  1000-node deployment would demote the host and re-mesh).
* **--fail-at** — fault injection: hard-exit mid-run to exercise the
  restart path end to end.

The ``lm-100m`` arch is the end-to-end example config (~100M params).
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline
from repro.distributed.sharding import rules_for_mesh
from repro.distributed.straggler import StragglerMonitor
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.optim import OptState, adamw_init, cosine_schedule

LM_100M = transformer.LMConfig(
    name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_head=64, d_ff=2048, vocab=32768, tie_embeddings=True,
    rope_theta=10_000.0, mlp_act="swiglu")


def resolve_config(arch: str, smoke: bool) -> transformer.LMConfig:
    if arch == "lm-100m":
        return LM_100M
    rec = configs.get(arch)
    if rec.family != "lm":
        raise SystemExit(f"train.py drives LM archs; {arch} is "
                         f"{rec.family} (see examples/ for other families)")
    return rec.smoke if smoke else rec.full


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--data", type=int, default=0, help="data-axis size")
    ap.add_argument("--model", type=int, default=1, help="model-axis size")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="fault injection: sys.exit at this step")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve_config(args.arch, args.smoke)
    n_dev = len(jax.devices())
    data_ax = args.data or max(1, n_dev // args.model)
    mesh = make_host_mesh(data=data_ax, model=args.model)
    rules = rules_for_mesh(mesh)
    print(f"training {cfg.name} on mesh {dict(mesh.shape)} "
          f"({cfg.param_count() / 1e6:.1f}M params, "
          f"{cfg.active_param_count() / 1e6:.1f}M active)")

    pspecs = transformer.param_specs(cfg, rules)
    psh = rules.tree_shardings(pspecs)
    osh = rules.tree_shardings(
        OptState(step=jax.sharding.PartitionSpec(), mu=pspecs, nu=pspecs))

    lr = cosine_schedule(args.lr, args.warmup, args.steps)
    step_fn = jax.jit(
        transformer.make_train_step(cfg, rules, lr=lr),
        donate_argnums=(0, 1))

    ckpt = (CheckpointManager(args.checkpoint_dir)
            if args.checkpoint_dir else None)
    start_step = 0

    with mesh:
        init = jax.jit(
            lambda k: transformer.init_params(k, cfg, ep=rules.tp,
                                              vocab_pad_to=rules.tp),
            out_shardings=psh)
        params = init(jax.random.key(args.seed))
        opt = jax.jit(adamw_init, out_shardings=osh)(params)

        if ckpt is not None and ckpt.latest_step() is not None:
            tree_like = {"params": params, "opt": opt}
            shardings = {"params": psh, "opt": osh}
            step, restored = ckpt.restore_latest(tree_like, shardings)
            params, opt = restored["params"], restored["opt"]
            start_step = step + 1
            print(f"restored checkpoint at step {step}; resuming "
                  f"from {start_step} on mesh {dict(mesh.shape)} (elastic)")

        pipe = TokenPipeline(
            seed=args.seed, batch=args.batch, seq_len=args.seq_len,
            vocab=cfg.vocab,
            sharding=rules.sharding(rules.batch_spec(args.batch), None))
        monitor = StragglerMonitor(
            on_warn=lambda s, dt, mu: print(
                f"  [straggler] step {s}: {dt * 1e3:.0f}ms "
                f"vs mean {mu * 1e3:.0f}ms"))

        it = pipe.iter_from(start_step)
        losses = []
        for step in range(start_step, args.steps):
            batch = next(it)
            monitor.start()
            params, opt, metrics = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            monitor.stop(step)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({monitor.mean_step_time * 1e3:.0f} ms/step)")
            if ckpt is not None and (step + 1) % args.checkpoint_every == 0:
                ckpt.save_async(step, {"params": params, "opt": opt})
            if args.fail_at and step == args.fail_at:
                print(f"[fault injection] dying at step {step}")
                if ckpt is not None:
                    ckpt.wait()
                sys.exit(17)
        if ckpt is not None:
            ckpt.save(args.steps - 1, {"params": params, "opt": opt})
            ckpt.wait()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{len(losses)} steps")
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps_run": len(losses), "start_step": start_step}


if __name__ == "__main__":
    main()
