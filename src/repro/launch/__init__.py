"""Launchers: production mesh, dry-run driver, training/serving loops.

mesh      make_production_mesh() — (16,16) single-pod / (2,16,16) multi-pod
cells     (arch × shape) -> step fn + abstract inputs + shardings
dryrun    lower+compile every cell; memory/cost/collective analysis
train     fault-tolerant training loop (checkpoint, straggler, elastic)
serve     serving loop (batch scheduler + KV-cache / BNN engine)
"""
