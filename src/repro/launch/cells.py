"""Cell builders: (architecture × input shape × mesh) -> lowered step.

For every dry-run cell this module produces

* the step function (train / prefill / decode / sample / serve),
* ``input_specs()``-style ShapeDtypeStruct stand-ins for all step inputs
  (weak-type-correct, shardable, no device allocation),
* the matching NamedSharding pytree for ``jax.jit(in_shardings=...)``.

Smoke mode swaps the FULL config for the reduced SMOKE config and shrinks
the input shapes so the same builder drives CPU tests.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.shapes import Shape
from repro.distributed.sharding import Rules
from repro.models import (convnext, dit, efficientnet, layers, transformer,
                          vit)
from repro.optim import OptState, adamw_init, sgdm_init


@dataclasses.dataclass
class CellBuild:
    arch_id: str
    shape_name: str
    kind: str
    step_fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    cfg: Any
    note: str = ""

    def lower(self):
        jitted = jax.jit(self.step_fn, in_shardings=self.in_shardings)
        return jitted.lower(*self.abstract_args)


class SkippedCell(Exception):
    """Raised for cells the assignment marks skip (reason in args[0])."""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _opt_specs(pspecs):
    return OptState(step=P(), mu=pspecs, nu=pspecs)


def _sgd_specs(pspecs):
    return OptState(step=P(), mu=pspecs, nu=None)


# --------------------------------------------------------------------------
# LM family
# --------------------------------------------------------------------------

def _lm_cell(rec, shape: Shape, rules: Rules, smoke: bool) -> CellBuild:
    cfg = rec.smoke if smoke else rec.full
    if shape.kind == "skip":
        raise SkippedCell(shape.note)
    b, s = shape.global_batch, shape.seq_len
    if smoke:
        b, s = max(2, rules.dp), 64 * max(1, rules.tp) // max(1, rules.tp)
        s = 64
    pspecs = transformer.param_specs(cfg, rules)
    params = transformer.abstract_params(cfg, ep=rules.tp,
                                          vocab_pad_to=rules.tp)
    psh = rules.tree_shardings(pspecs)

    if shape.kind == "train":
        step = transformer.make_train_step(cfg, rules)
        opt = jax.eval_shape(adamw_init, params)
        osh = rules.tree_shardings(_opt_specs(pspecs))
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        bsh = rules.tree_shardings(
            {"tokens": P(rules.batch_spec(b), None),
             "labels": P(rules.batch_spec(b), None)})
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, opt, batch), (psh, osh, bsh), cfg)

    if shape.kind == "prefill":
        step = transformer.make_prefill_step(cfg, rules, max_seq=s)
        tokens = _sds((b, s), jnp.int32)
        tsh = rules.named(P(rules.batch_spec(b), None))
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, tokens), (psh, tsh), cfg)

    if shape.kind == "decode":
        # Weights-stationary serving: FSDP sharding would re-gather the
        # full parameter set (command-r: the 2.1 GB bf16 head alone)
        # EVERY token.  Decode replicates params over the data axis
        # (no optimizer states at serve time — they fit) and keeps only
        # the TP sharding.
        serve_rules = dataclasses.replace(rules, fsdp=None)
        pspecs = transformer.param_specs(cfg, serve_rules)
        psh = rules.tree_shardings(pspecs)
        step = transformer.make_decode_step(cfg, rules, max_seq=s)
        cache = transformer.abstract_cache(cfg, b, s)
        csh = rules.tree_shardings(
            transformer.cache_specs(cfg, rules, b, s))
        tokens = _sds((b, 1), jnp.int32)
        tsh = rules.named(P(rules.batch_spec(b), None))
        pos = _sds((), jnp.int32)
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, cache, tokens, pos),
                         (psh, csh, tsh, rules.named(P())), cfg)

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------
# Diffusion family
# --------------------------------------------------------------------------

def _dit_cell(rec, shape: Shape, rules: Rules, smoke: bool) -> CellBuild:
    cfg = rec.smoke if smoke else rec.full
    b, res = shape.batch, shape.img_res
    if smoke:
        b, res = max(2, rules.dp), cfg.img_res
    lat = res // cfg.vae_downsample
    pspecs = dit.param_specs(cfg, rules)
    params = dit.abstract_params(cfg)
    psh = rules.tree_shardings(pspecs)
    bspec = rules.batch_spec(b)

    if shape.kind == "train":
        step = dit.make_train_step(cfg, rules)
        opt = jax.eval_shape(adamw_init, params)
        osh = rules.tree_shardings(_opt_specs(pspecs))
        batch = {"latents": _sds((b, lat, lat, cfg.latent_channels),
                                 jnp.float32),
                 "labels": _sds((b,), jnp.int32),
                 "t": _sds((b,), jnp.int32),
                 "noise": _sds((b, lat, lat, cfg.latent_channels),
                               jnp.float32)}
        bsh = rules.tree_shardings(
            {"latents": P(bspec, None, None, None),
             "labels": P(bspec), "t": P(bspec),
             "noise": P(bspec, None, None, None)})
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, opt, batch), (psh, osh, bsh), cfg,
                         note=f"steps={shape.steps}")

    if shape.kind == "sample":
        step = dit.make_sample_step(cfg, rules)
        x_t = _sds((b, lat, lat, cfg.latent_channels),
                   layers.COMPUTE_DTYPE)
        args = (params, x_t, _sds((b,), jnp.int32), _sds((b,), jnp.int32),
                _sds((b,), jnp.int32))
        shard = (psh, rules.named(P(bspec, None, None, None)),
                 rules.named(P(bspec)), rules.named(P(bspec)),
                 rules.named(P(bspec)))
        return CellBuild(rec.arch_id, shape.name, shape.kind, step, args,
                         shard, cfg, note=f"steps={shape.steps} (1 lowered)")

    raise ValueError(shape.kind)


# --------------------------------------------------------------------------
# Vision family
# --------------------------------------------------------------------------

def _vision_common(rec, shape: Shape, rules: Rules, smoke: bool):
    cfg = rec.smoke if smoke else rec.full
    b, res = shape.batch, shape.img_res
    if smoke:
        b, res = max(2, rules.dp), cfg.img_res
    return cfg, b, res


def _vit_cell(rec, shape, rules, smoke) -> CellBuild:
    cfg, b, res = _vision_common(rec, shape, rules, smoke)
    pspecs = vit.param_specs(cfg, rules)
    params = vit.abstract_params(cfg)
    psh = rules.tree_shardings(pspecs)
    bspec = rules.batch_spec(b)
    images = _sds((b, res, res, 3), jnp.float32)
    ish = rules.named(P(bspec, None, None, None))

    if shape.kind == "train":
        step = vit.make_train_step(cfg, rules)
        opt = jax.eval_shape(adamw_init, params)
        osh = rules.tree_shardings(_opt_specs(pspecs))
        batch = {"images": images, "labels": _sds((b,), jnp.int32)}
        bsh = rules.tree_shardings(
            {"images": P(bspec, None, None, None), "labels": P(bspec)})
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, opt, batch), (psh, osh, bsh), cfg)
    step = functools.partial(
        lambda p, x: vit.forward(p, x, cfg, rules))
    return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                     (params, images), (psh, ish), cfg)


def _convnext_cell(rec, shape, rules, smoke) -> CellBuild:
    cfg, b, res = _vision_common(rec, shape, rules, smoke)
    pspecs = convnext.param_specs(cfg, rules)
    params = convnext.abstract_params(cfg)
    psh = rules.tree_shardings(pspecs)
    bspec = rules.batch_spec(b)
    images = _sds((b, res, res, 3), jnp.float32)
    ish = rules.named(P(bspec, None, None, None))

    if shape.kind == "train":
        step = convnext.make_train_step(cfg, rules)
        opt = jax.eval_shape(adamw_init, params)
        osh = rules.tree_shardings(_opt_specs(pspecs))
        batch = {"images": images, "labels": _sds((b,), jnp.int32)}
        bsh = rules.tree_shardings(
            {"images": P(bspec, None, None, None), "labels": P(bspec)})
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, opt, batch), (psh, osh, bsh), cfg)
    step = functools.partial(
        lambda p, x: convnext.forward(p, x, cfg, rules))
    return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                     (params, images), (psh, ish), cfg)


def _effnet_cell(rec, shape, rules, smoke) -> CellBuild:
    cfg, b, res = _vision_common(rec, shape, rules, smoke)
    pspecs, sspecs = efficientnet.param_specs(cfg, rules)
    params, state = efficientnet.abstract_params(cfg)
    psh = rules.tree_shardings(pspecs)
    ssh = rules.tree_shardings(sspecs)
    bspec = rules.batch_spec(b)
    images = _sds((b, res, res, 3), jnp.float32)
    ish = rules.named(P(bspec, None, None, None))

    if shape.kind == "train":
        step = efficientnet.make_train_step(cfg, rules)
        opt = jax.eval_shape(sgdm_init, params)
        osh = rules.tree_shardings(_sgd_specs(pspecs))
        batch = {"images": images, "labels": _sds((b,), jnp.int32)}
        bsh = rules.tree_shardings(
            {"images": P(bspec, None, None, None), "labels": P(bspec)})
        return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                         (params, state, opt, batch),
                         (psh, ssh, osh, bsh), cfg)
    step = functools.partial(
        lambda p, s, x: efficientnet.apply(p, s, x, cfg, rules,
                                           train=False)[0])
    return CellBuild(rec.arch_id, shape.name, shape.kind, step,
                     (params, state, images), (psh, ssh, ish), cfg)


_VISION_BUILDERS = {
    "vit-l16": _vit_cell,
    "vit-h14": _vit_cell,
    "convnext-b": _convnext_cell,
    "efficientnet-b7": _effnet_cell,
}


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def input_specs(arch_id: str, shape_name: str, rules: Rules) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the cell's step
    (weak-type-correct, shardable, no device allocation) — the tuple
    passed to ``jax.jit(step).lower(*input_specs(...))``."""
    return build_cell(arch_id, shape_name, rules).abstract_args


def build_cell(arch_id: str, shape_name: str, rules: Rules,
               smoke: bool = False,
               overrides: dict | None = None) -> CellBuild:
    """overrides: dataclasses.replace(...) fields applied to the config
    (dry-run probes: n_layers=1/2; vision exact counting: unroll=True)."""
    rec = configs.get(arch_id)
    if overrides:
        rec = dataclasses.replace(
            rec, full=dataclasses.replace(rec.full, **overrides),
            smoke=dataclasses.replace(rec.smoke, **overrides))
    shape = rec.shape(shape_name)
    if shape.kind == "skip":
        raise SkippedCell(shape.note)
    if rec.family == "lm":
        return _lm_cell(rec, shape, rules, smoke)
    if rec.family == "diffusion":
        return _dit_cell(rec, shape, rules, smoke)
    if rec.family == "vision":
        return _VISION_BUILDERS[arch_id](rec, shape, rules, smoke)
    raise ValueError(rec.family)
