"""Shared benchmark utilities: CSV emission (+ timer re-exports).

The timers live in :mod:`benchmarks.timing` (one implementation of the
min-of-budget and median estimators instead of per-harness copies);
``time_fn`` is re-exported here for the existing call sites.

CPU-timing caveat: these harnesses time the pure-JAX ("xla") execution
path on the host CPU — meaningful for RELATIVE comparisons (binary vs
float engine, layer by layer), which is what the paper's tables report.
Absolute TPU numbers come from the dry-run roofline (benchmarks/roofline).
"""

from __future__ import annotations

from benchmarks.timing import time_fn, time_stable  # noqa: F401
# Every BENCH_*.json goes out through the provenance-stamping writer
# (DESIGN.md §10.4): a ``meta`` block with git sha, jax/jaxlib versions,
# device kind/count, backend list and a UTC timestamp.
from repro.obs.provenance import write_bench  # noqa: F401


def skipped(reason: str) -> dict:
    """Structured "not measured" marker for BENCH_*.json fields.

    Downstream trajectory tooling reads every bench field as a row; a
    bare ``null`` forces every consumer to special-case it.  A skipped
    measurement instead carries *why* it was skipped:
    ``{"skipped": "1 device"}``."""
    return {"skipped": reason}


def is_skipped(value) -> bool:
    return isinstance(value, dict) and "skipped" in value


def emit(rows: list[dict], title: str) -> None:
    if not rows:
        print(f"# {title}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# {title}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))
    print()


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
