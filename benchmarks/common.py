"""Shared benchmark utilities: timing, CSV emission, tiny-model helpers.

CPU-timing caveat: these harnesses time the pure-JAX ("xla") execution
path on the host CPU — meaningful for RELATIVE comparisons (binary vs
float engine, layer by layer), which is what the paper's tables report.
Absolute TPU numbers come from the dry-run roofline (benchmarks/roofline).
"""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after compile warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(rows: list[dict], title: str) -> None:
    if not rows:
        print(f"# {title}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# {title}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))
    print()


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
