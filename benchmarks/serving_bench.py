"""Serving-path benchmark: sync vs async dispatch, single vs sharded.

Measures end-to-end serving throughput and latency through the
:class:`~repro.serving.server.InferenceServer` — the whole subsystem
(scheduler assembly, bucket padding, executable-cache dispatch, result
scatter), not just the kernel — and writes the machine-readable
``BENCH_serving.json`` perf artifact:

* **sync vs async**: the synchronous drain loop (block on every batch)
  against async double-buffered dispatch (batch k+1 dispatched while
  batch k is in flight).  Same engine, same precompiled executables —
  the delta is purely the overlap of host-side batch assembly/scatter
  with device compute.
* **single vs sharded**: when >1 device is visible, the same stream with
  data-parallel batch sharding over a host mesh.

Networks are the paper's (YOLOv2-Tiny is fully convolutional, so it also
runs at reduced resolutions where serving overhead — not conv FLOPs —
dominates and the async win is largest).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, skipped, write_bench


def _serve_stream(engine, hwc, *, requests: int, max_batch: int,
                  buckets: tuple[int, ...], async_dispatch: bool,
                  mesh=None) -> dict:
    from repro.serving import InferenceServer

    server = InferenceServer(engine, max_batch=max_batch, max_wait_s=0.0,
                             buckets=buckets,
                             async_dispatch=async_dispatch, mesh=mesh)
    server.compile_buckets()
    rng = np.random.default_rng(0)
    for _ in range(requests):
        server.submit(rng.integers(0, 256, hwc, dtype=np.uint8))
    server.drain()
    return server.metrics()


def _best(runs: list[dict]) -> dict:
    return max(runs, key=lambda m: m["throughput"] or 0)


def bench_network(name: str, *, input_hw: int | None = None,
                  requests: int = 32, max_batch: int = 8,
                  matmul_mode: str = "xla", trials: int = 2) -> dict:
    from repro.models import paper_nets
    from repro.serving import PhoneBitEngine, buckets_for

    spec, (h, w, c), params = paper_nets.init(name)
    if input_hw:
        h = w = input_hw
    engine = PhoneBitEngine.from_trained(params, spec, (h, w),
                                         matmul_mode=matmul_mode)
    buckets = buckets_for(max_batch)
    kw = dict(requests=requests, max_batch=max_batch, buckets=buckets)
    # Paired measurement: alternate sync/async streams back-to-back and
    # take the MEDIAN of per-pair throughput ratios.  Machine drift on a
    # shared host moves both streams of a pair together and cancels in
    # the ratio, where a best-of comparison across minutes would be
    # dominated by it; per-mode metrics still report each mode's best
    # stream.
    sync_runs, async_runs, ratios = [], [], []
    for _ in range(trials):
        s = _serve_stream(engine, (h, w, c), async_dispatch=False, **kw)
        a = _serve_stream(engine, (h, w, c), async_dispatch=True, **kw)
        sync_runs.append(s)
        async_runs.append(a)
        if s["throughput"] and a["throughput"]:
            ratios.append(a["throughput"] / s["throughput"])
    sync, async_ = _best(sync_runs), _best(async_runs)
    paired = sorted(ratios)[len(ratios) // 2] if ratios else None

    # On a 1-device host the sharded stream cannot run; the row says so
    # instead of emitting a bare null (see benchmarks.common.skipped).
    n_dev = len(jax.devices())
    sharded = skipped(f"{n_dev} device")
    if n_dev > 1:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(data=n_dev, model=1)
        sharded = _best([_serve_stream(engine, (h, w, c),
                                       async_dispatch=True, mesh=mesh,
                                       **kw) for _ in range(trials)])
    row = {
        "network": name, "input_hw": h, "requests": requests,
        "max_batch": max_batch, "buckets": list(buckets),
        "matmul_mode": matmul_mode,
        "sync": sync, "async": async_, "sharded": sharded,
        # median of paired ratios — the drift-robust speedup estimate
        "async_speedup": paired,
        "async_speedup_pairs": [round(r, 4) for r in ratios],
        "shard_speedup": (sharded["throughput"] / async_["throughput"]
                          if sharded.get("throughput")
                          and async_["throughput"]
                          else skipped(f"{n_dev} device")),
    }
    return row


def run(smoke: bool = False, out: str = "BENCH_serving.json") -> dict:
    # Double-buffering pays in the overhead-dominated regime — small
    # per-dispatch device work (single-image buckets, reduced resolution)
    # where per-request host staging/dispatch/readback is comparable to
    # compute and async hides it behind the in-flight batch.  At
    # compute-saturated CPU shapes the device *is* the host (XLA's
    # threads and the serving loop share cores), so overlap buys nothing
    # there — that row is reported anyway; the TPU/serving-shard regime
    # is the small-per-device-work one.
    if smoke:
        # CI tripwire: the fully-conv paper net, latency-serving shape.
        cases = [dict(name="yolov2-tiny", input_hw=32, requests=64,
                      max_batch=1, trials=5)]
    else:
        cases = [
            dict(name="yolov2-tiny", input_hw=None, requests=16,
                 max_batch=4),
            dict(name="yolov2-tiny", input_hw=32, requests=96,
                 max_batch=1, trials=9),
            dict(name="alexnet", input_hw=None, requests=16, max_batch=4),
        ]
    rows = [bench_network(c.pop("name"), **c) for c in cases]

    csv_rows = [{
        "network": r["network"], "hw": r["input_hw"],
        "sync_img_s": r["sync"]["throughput"],
        "async_img_s": r["async"]["throughput"],
        "async_speedup": r["async_speedup"],
        "async_p50_ms": r["async"]["p50_ms"],
        "async_p95_ms": r["async"]["p95_ms"],
        "shard_img_s": r["sharded"].get("throughput", ""),
    } for r in rows]
    emit(csv_rows, "§Serving: sync vs async (vs sharded) throughput")

    report = {
        "device": f"{jax.default_backend()}:"
                  f"{jax.devices()[0].device_kind}",
        "n_devices": len(jax.devices()),
        "smoke": smoke,
        "nets": rows,
        "summary": {
            "n_nets": len(rows),
            "async_wins": sum(1 for r in rows
                              if (r["async_speedup"] or 0) > 1.0),
            "best_async_speedup": max((r["async_speedup"] or 0)
                                      for r in rows),
        },
    }
    report = write_bench(out, report)
    print(f"wrote {out} (async wins "
          f"{report['summary']['async_wins']}/{len(rows)}, best speedup "
          f"{report['summary']['best_async_speedup']:.2f}x)")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.serving_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized single case; still writes "
                         "BENCH_serving.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
