"""Serving-path benchmark: sync vs async dispatch, single vs multi-device.

Measures end-to-end serving throughput and latency through the
:class:`~repro.serving.server.InferenceServer` — the whole subsystem
(scheduler assembly, bucket padding, executable-cache dispatch, result
scatter), not just the kernel — and writes the machine-readable
``BENCH_serving.json`` perf artifact:

* **sync vs async**: the synchronous drain loop (block on every batch)
  against async double-buffered dispatch (batch k+1 dispatched while
  batch k is in flight).  Same engine, same precompiled executables —
  the delta is purely the overlap of host-side batch assembly/scatter
  with device compute.
* **single vs sharded vs pipelined**: the same stream under both
  placements (DESIGN.md §13) — data-parallel batch sharding
  (``DataParallel``) and pipeline stages cut at HBM touch points
  (``Pipelined``).  These need >1 device, so they run in a SUBPROCESS
  on a forced 4-device host mesh
  (``--xla_force_host_platform_device_count``), together with their own
  single-device baseline so the speedup ratios are self-consistent.
  Forced host devices share the machine's cores: the rows verify the
  placement path end to end and calibrate its overhead; the ratios
  become real speedups only on genuinely parallel hardware.  The rows
  are marked ``skipped`` only when the subprocess cannot be spawned at
  all.

Networks are the paper's (YOLOv2-Tiny is fully convolutional, so it also
runs at reduced resolutions where serving overhead — not conv FLOPs —
dominates and the async win is largest).

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

import jax
import numpy as np

from benchmarks.common import emit, skipped, write_bench

MESH_DEVICES = 4

_MD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
import sys
sys.path.insert(0, {src!r})
import json
import jax
import numpy as np
from repro.distributed import DataParallel, Pipelined
from repro.models import paper_nets
from repro.serving import InferenceServer, PhoneBitEngine, buckets_for

spec, (h, w, c), params = paper_nets.init({name!r})
if {input_hw!r}:
    h = w = {input_hw!r}
engine = PhoneBitEngine.from_trained(params, spec, (h, w),
                                     matmul_mode={matmul_mode!r})

def serve(placement):
    server = InferenceServer(engine, max_batch={max_batch},
                             max_wait_s=0.0,
                             buckets=buckets_for({max_batch}),
                             async_dispatch=True, placement=placement)
    server.compile_buckets()
    rng = np.random.default_rng(0)
    for _ in range({requests}):
        server.submit(rng.integers(0, 256, (h, w, c), dtype=np.uint8))
    server.drain()
    return server.metrics()

out = dict(
    baseline=serve(None),
    sharded=serve(DataParallel.over({n_dev})),
    pipelined=serve(Pipelined.over({n_dev})),
)
print("BENCHJSON:" + json.dumps(out))
"""


def _multi_device_rows(name: str, *, input_hw: int | None,
                       requests: int, max_batch: int,
                       matmul_mode: str, n_dev: int = MESH_DEVICES,
                       timeout: int = 900) -> dict:
    """Sharded + pipelined serving metrics, measured on a forced
    ``n_dev``-device host mesh in a subprocess (the placeholder-device
    flag must be set before jax imports and must not leak into this
    process).  Returns the three streams' metrics; ``skipped`` rows only
    when the subprocess itself cannot be spawned."""
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    script = _MD_SCRIPT.format(n_dev=n_dev, src=src, name=name,
                               input_hw=input_hw,
                               matmul_mode=matmul_mode,
                               max_batch=max_batch, requests=requests)
    try:
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True,
                           timeout=timeout)
    except OSError as e:          # spawn itself failed: report why
        return {k: skipped(f"subprocess spawn failed: {e}")
                for k in ("baseline", "sharded", "pipelined")}
    if r.returncode != 0:         # a real failure must fail the bench
        raise RuntimeError(
            f"multi-device bench subprocess failed for {name}:\n"
            f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    payload = [l for l in r.stdout.splitlines()
               if l.startswith("BENCHJSON:")]
    if not payload:
        raise RuntimeError(f"multi-device bench emitted no payload for "
                           f"{name}:\n{r.stdout}")
    return json.loads(payload[-1][len("BENCHJSON:"):])


def _serve_stream(engine, hwc, *, requests: int, max_batch: int,
                  buckets: tuple[int, ...], async_dispatch: bool) -> dict:
    from repro.serving import InferenceServer

    server = InferenceServer(engine, max_batch=max_batch, max_wait_s=0.0,
                             buckets=buckets,
                             async_dispatch=async_dispatch)
    server.compile_buckets()
    rng = np.random.default_rng(0)
    for _ in range(requests):
        server.submit(rng.integers(0, 256, hwc, dtype=np.uint8))
    server.drain()
    return server.metrics()


def _best(runs: list[dict]) -> dict:
    return max(runs, key=lambda m: m["throughput"] or 0)


def _ratio(num: dict, den: dict):
    if num.get("throughput") and den.get("throughput"):
        return num["throughput"] / den["throughput"]
    return None


def bench_network(name: str, *, input_hw: int | None = None,
                  requests: int = 32, max_batch: int = 8,
                  matmul_mode: str = "xla", trials: int = 2) -> dict:
    from repro.models import paper_nets
    from repro.serving import PhoneBitEngine, buckets_for

    spec, (h, w, c), params = paper_nets.init(name)
    if input_hw:
        h = w = input_hw
    engine = PhoneBitEngine.from_trained(params, spec, (h, w),
                                         matmul_mode=matmul_mode)
    buckets = buckets_for(max_batch)
    kw = dict(requests=requests, max_batch=max_batch, buckets=buckets)
    # Paired measurement: alternate sync/async streams back-to-back and
    # take the MEDIAN of per-pair throughput ratios.  Machine drift on a
    # shared host moves both streams of a pair together and cancels in
    # the ratio, where a best-of comparison across minutes would be
    # dominated by it; per-mode metrics still report each mode's best
    # stream.
    sync_runs, async_runs, ratios = [], [], []
    for _ in range(trials):
        s = _serve_stream(engine, (h, w, c), async_dispatch=False, **kw)
        a = _serve_stream(engine, (h, w, c), async_dispatch=True, **kw)
        sync_runs.append(s)
        async_runs.append(a)
        if s["throughput"] and a["throughput"]:
            ratios.append(a["throughput"] / s["throughput"])
    sync, async_ = _best(sync_runs), _best(async_runs)
    paired = sorted(ratios)[len(ratios) // 2] if ratios else None

    # Placement rows on the forced 4-device mesh; speedups are vs the
    # SAME subprocess's single-device baseline (self-consistent ratios —
    # the parent's async stream ran under a different device config).
    md = _multi_device_rows(name, input_hw=input_hw, requests=requests,
                            max_batch=max_batch,
                            matmul_mode=matmul_mode)
    row = {
        "network": name, "input_hw": h, "requests": requests,
        "max_batch": max_batch, "buckets": list(buckets),
        "matmul_mode": matmul_mode,
        "sync": sync, "async": async_,
        "sharded": md["sharded"], "pipelined": md["pipelined"],
        "multi_device": {
            "n_devices": MESH_DEVICES,
            "forced_host_mesh": True,
            "baseline": md["baseline"],
        },
        # median of paired ratios — the drift-robust speedup estimate
        "async_speedup": paired,
        "async_speedup_pairs": [round(r, 4) for r in ratios],
        "shard_speedup": _ratio(md["sharded"], md["baseline"]),
        "pipeline_speedup": _ratio(md["pipelined"], md["baseline"]),
    }
    return row


def run(smoke: bool = False, out: str = "BENCH_serving.json") -> dict:
    # Double-buffering pays in the overhead-dominated regime — small
    # per-dispatch device work (single-image buckets, reduced resolution)
    # where per-request host staging/dispatch/readback is comparable to
    # compute and async hides it behind the in-flight batch.  At
    # compute-saturated CPU shapes the device *is* the host (XLA's
    # threads and the serving loop share cores), so overlap buys nothing
    # there — that row is reported anyway; the TPU/serving-shard regime
    # is the small-per-device-work one.
    if smoke:
        # CI tripwire: the fully-conv paper net, latency-serving shape.
        cases = [dict(name="yolov2-tiny", input_hw=32, requests=64,
                      max_batch=1, trials=5)]
    else:
        cases = [
            dict(name="yolov2-tiny", input_hw=None, requests=16,
                 max_batch=4),
            dict(name="yolov2-tiny", input_hw=32, requests=96,
                 max_batch=1, trials=9),
            dict(name="alexnet", input_hw=None, requests=16, max_batch=4),
        ]
    rows = [bench_network(c.pop("name"), **c) for c in cases]

    csv_rows = [{
        "network": r["network"], "hw": r["input_hw"],
        "sync_img_s": r["sync"]["throughput"],
        "async_img_s": r["async"]["throughput"],
        "async_speedup": r["async_speedup"],
        "async_p50_ms": r["async"]["p50_ms"],
        "async_p95_ms": r["async"]["p95_ms"],
        "shard_img_s": r["sharded"].get("throughput", ""),
        "pipeline_img_s": r["pipelined"].get("throughput", ""),
    } for r in rows]
    emit(csv_rows, "§Serving: sync vs async vs sharded vs pipelined")

    report = {
        "device": f"{jax.default_backend()}:"
                  f"{jax.devices()[0].device_kind}",
        "n_devices": len(jax.devices()),
        "mesh_devices": MESH_DEVICES,
        "smoke": smoke,
        "nets": rows,
        "summary": {
            "n_nets": len(rows),
            "async_wins": sum(1 for r in rows
                              if (r["async_speedup"] or 0) > 1.0),
            "best_async_speedup": max((r["async_speedup"] or 0)
                                      for r in rows),
            "sharded_measured": sum(
                1 for r in rows if r["sharded"].get("throughput")),
            "pipelined_measured": sum(
                1 for r in rows if r["pipelined"].get("throughput")),
        },
    }
    report = write_bench(out, report)
    print(f"wrote {out} (async wins "
          f"{report['summary']['async_wins']}/{len(rows)}, best speedup "
          f"{report['summary']['best_async_speedup']:.2f}x, "
          f"placement rows measured "
          f"{report['summary']['sharded_measured']}+"
          f"{report['summary']['pipelined_measured']}/{2 * len(rows)})")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.serving_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized single case; still writes "
                         "BENCH_serving.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
