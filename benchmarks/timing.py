"""Shared benchmark timers (single home; see the satellite note in
``benchmarks/common.py``).

Two estimators, two regimes:

* :func:`time_stable` — **min of a time budget**: repeat until
  ``budget_s`` wall seconds are spent (capped at ``max_iters``) and
  return the *minimum*.  The noise-robust microbenchmark estimator on a
  shared host, where external interference only ever adds time.  Used by
  the kernel microbenchmarks.
* :func:`time_fn` — **median of N**: the cheaper estimator for
  macro-level rows (whole-workload latency) where each call is expensive
  and drift is handled at a higher level (paired streams, ratios).
"""

from __future__ import annotations

import time
from typing import Callable

import jax

from repro.obs.metrics import percentile


def time_stable(fn: Callable, *args, budget_s: float = 0.3,
                max_iters: int = 24, warmup: int = 2) -> float:
    """Minimum wall seconds per call over a spent-time budget."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best, spent, it = float("inf"), 0.0, 0
    while spent < budget_s and it < max_iters:
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        best, spent, it = min(best, dt), spent + dt, it + 1
    return best


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after compile warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    # Canonical latency math (repro.obs.metrics): nearest-rank p50 ==
    # the median for the odd iteration counts benchmarks use.
    return float(percentile(sorted(times), 0.5))
