"""§Roofline: three-term analysis from the cached dry-run artifacts.

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and prints
per (arch × shape × mesh):

    compute    = HLO_FLOPs_per_device / 197 TF/s   (bf16 peak, v5e)
    memory     = HLO_bytes_per_device / 819 GB/s   (HBM)
    collective = wire_bytes_per_device / 50 GB/s   (ICI link)

plus the dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), the
roofline fraction (score), and a one-line "what would move the bound"
note derived from the dominant term.
"""

from __future__ import annotations

import json
import pathlib

from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

ADVICE = {
    "compute": "more useful-FLOPs fraction: cut remat recompute / masked "
               "attention waste, or grow per-device batch",
    "memory": "cut bytes/FLOP: fuse attention chain (Pallas flash "
              "kernel), fewer f32 staging buffers, larger matmul tiles",
    "collective": "cut wire bytes: reshard weights (FSDP gather in bf16), "
                  "overlap collectives with compute, 2D weight layouts",
}


def load(out_dir: str = "artifacts/dryrun") -> list[dict]:
    rows = []
    for f in sorted(pathlib.Path(out_dir).glob("*.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def run(out_dir: str = "artifacts/dryrun") -> list[dict]:
    rows = load(out_dir)
    ok = [r for r in rows if not r.get("skipped") and not r.get("failed")]
    print("# §Roofline — per-cell three-term analysis (TPU v5e: "
          f"{PEAK_FLOPS / 1e12:.0f} TF bf16, {HBM_BW / 1e9:.0f} GB/s HBM, "
          f"{ICI_BW / 1e9:.0f} GB/s ICI)")
    hdr = (f"{'arch':24} {'shape':12} {'mesh':8} {'t_comp_s':>9} "
           f"{'t_mem_s':>9} {'t_coll_s':>9} {'bound':>10} {'useful':>7} "
           f"{'roofline':>8}")
    print(hdr)
    for r in ok:
        print(f"{r['arch']:24} {r['shape']:12} {r['mesh']:8} "
              f"{r['t_compute']:9.4f} {r['t_memory']:9.4f} "
              f"{r['t_collective']:9.4f} {r['bottleneck']:>10} "
              f"{r['useful_flops_ratio']:7.3f} "
              f"{r['roofline_fraction']:8.3f}")
    skipped = [r for r in rows if r.get("skipped")]
    failed = [r for r in rows if r.get("failed")]
    print(f"\n{len(ok)} cells analyzed, {len(skipped)} skipped "
          f"(long_500k on full-attention archs), {len(failed)} failed")
    for r in failed:
        print("  FAILED:", r["arch"], r["shape"], r.get("mesh"))
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        print(f"\nworst roofline fraction: {worst['arch']} "
              f"{worst['shape']} ({worst['roofline_fraction']:.3f}) — "
              f"{ADVICE[worst['bottleneck']]}")
    return rows


if __name__ == "__main__":
    run()
