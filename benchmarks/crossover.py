"""VPU-popcount vs MXU-±1 crossover (DESIGN.md §3 beyond-paper analysis).

The paper's xor+popcount algorithm is optimal on wide-bitwise-SIMD
hardware; the TPU's MXU is ~50× stronger at matmuls than the VPU is at
int32 ops, so there is a crossover where unpacking to ±1 and feeding the
systolic array wins despite the 32× data expansion (expansion happens
HBM→VMEM once per tile, HBM traffic stays packed).

Analytic model per (M, N, K-bit) binary matmul on v5e:

  VPU path:   words = K/32;  t_vpu = M·N·words · c_vpu
              (c_vpu: xor+popcount+acc ≈ 3 int32 lane-ops at ~2.5e12
              lane-ops/s ⇒ 1.2e-12 s/word-op)
  MXU path:   t_mxu = 2·M·N·K / 197e12  (bf16 FLOPs at peak)

  Both read the same packed HBM bytes (M·K/8 + N·K/8).

Host-CPU wall times for the two pure-JAX impls are printed alongside as
directional evidence (CPU exposes the GEMM engine but not the bitwise
SIMD, so the measured crossover favors pm1 earlier than the TPU model).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import binary_ops, packing

_VPU_LANE_OPS = 2.5e12     # int32 lane-ops/s (8x128 lanes @ ~940 MHz ·ops)
_MXU_FLOPS = 197e12


def analytic_crossover(m: int, n: int, k_bits: int) -> dict:
    words = k_bits / 32.0
    t_vpu = m * n * words * 3.0 / _VPU_LANE_OPS
    t_mxu = 2.0 * m * n * k_bits / _MXU_FLOPS
    return dict(t_vpu_us=t_vpu * 1e6, t_mxu_us=t_mxu * 1e6,
                mxu_wins=bool(t_mxu < t_vpu))


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    m = n = 256
    for k_bits in (256, 1024, 4096, 16384):
        a = rng.choice([-1.0, 1.0], size=(m, k_bits)).astype(np.float32)
        b = rng.choice([-1.0, 1.0], size=(n, k_bits)).astype(np.float32)
        ap = packing.pack_signs(jnp.asarray(a))
        bp = packing.pack_signs(jnp.asarray(b))

        t_xor = time_fn(jax.jit(
            lambda x, y: binary_ops.packed_matmul_counts(x, y,
                                                         impl="xor")),
            ap, bp)
        t_pm1 = time_fn(jax.jit(
            lambda x, y: binary_ops.packed_matmul_counts(x, y,
                                                         impl="pm1")),
            ap, bp)
        model = analytic_crossover(m, n, k_bits)
        rows.append(dict(
            m=m, n=n, k_bits=k_bits,
            host_xor_ms=round(t_xor * 1e3, 3),
            host_pm1_ms=round(t_pm1 * 1e3, 3),
            tpu_model_vpu_us=round(model["t_vpu_us"], 2),
            tpu_model_mxu_us=round(model["t_mxu_us"], 2),
            tpu_model_winner="mxu" if model["mxu_wins"] else "vpu",
        ))
    emit(rows, "Crossover — paper's VPU popcount vs beyond-paper MXU ±1 "
               "(host wall + TPU analytic model)")
    return rows


if __name__ == "__main__":
    run()
