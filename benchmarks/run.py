"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One harness per paper table/figure, the per-kernel microbench (which
writes the machine-readable ``BENCH_kernels.json`` perf artifact), and the
roofline reader (which consumes cached dry-run artifacts if present).
Each harness prints a CSV block.

``--smoke`` runs CI-sized shapes — a fast regression tripwire that still
writes the BENCH artifacts; ``--only {kernels,serving,workloads,
endurance,coldstart}`` restricts the run to one suite (and composes with
``--smoke``: ``--smoke --only serving`` is the serving tripwire alone).
"""

from __future__ import annotations

import argparse
import pathlib
import traceback

#: ``--only`` choices: each names one suite; the callable gets
#: ``smoke=`` so ``--smoke --only X`` runs X's CI-sized variant.
ONLY_SUITES = ("kernels", "serving", "workloads", "endurance", "coldstart")


def _suite_runner(only: str):
    from benchmarks import (coldstart_bench, endurance_bench,
                            kernels_bench, serving_bench, workloads_bench)

    return {
        "kernels": kernels_bench.run,
        "serving": serving_bench.run,
        "workloads": workloads_bench.run,
        "endurance": endurance_bench.run,
        "coldstart": coldstart_bench.run,
    }[only]


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(prog="benchmarks.run")
    parser.add_argument("--smoke", action="store_true",
                        help="CI-sized shapes (fast tripwire; still "
                             "writes the BENCH_*.json artifacts)")
    parser.add_argument("--only", choices=ONLY_SUITES, default=None,
                        help="run one suite instead of everything; "
                             "composes with --smoke")
    args = parser.parse_args(argv)

    if args.only is not None:
        _suite_runner(args.only)(smoke=args.smoke)
        return

    from benchmarks import (coldstart_bench, crossover, endurance_bench,
                            fig5_layers, graph_plan, kernels_bench,
                            roofline, serving_bench, table2_model_size,
                            table3_runtime, table4_energy,
                            workloads_bench)

    if args.smoke:
        kernels_bench.run(smoke=True)
        workloads_bench.run(smoke=True)
        return

    t3_rows = None
    for name, fn in (
            ("table2_model_size", table2_model_size.run),
            ("table3_runtime", table3_runtime.run),
            ("fig5_layers", fig5_layers.run),
            ("graph_plan", graph_plan.run),
            ("kernels_bench", kernels_bench.run),
            ("serving_bench", serving_bench.run),
            ("endurance_bench", endurance_bench.run),
            ("coldstart_bench", coldstart_bench.run),
            ("workloads_bench", workloads_bench.run),
            ("crossover", crossover.run),
    ):
        try:
            out = fn()
            if name == "table3_runtime":
                t3_rows = out
        except Exception:
            print(f"!! {name} failed:")
            traceback.print_exc()

    try:
        table4_energy.run(t3_rows)
    except Exception:
        print("!! table4_energy failed:")
        traceback.print_exc()

    if pathlib.Path("artifacts/dryrun").exists():
        try:
            roofline.run()
        except Exception:
            print("!! roofline failed:")
            traceback.print_exc()
    else:
        print("# §Roofline: no artifacts/dryrun cache — run "
              "`python -m repro.launch.dryrun --all` first")


if __name__ == "__main__":
    main()
