"""Paper Tab III: end-to-end inference runtime, BNN engine vs float CNN.

The paper compares PhoneBit against CNNdroid / TFLite float executions on
two phones.  The reproducible core of that table is the *engine-level*
speedup: the same network executed (a) by the packed binary engine and
(b) as a full-precision CNN — both through identical JAX/XLA plumbing, so
the ratio isolates the PhoneBit technique (1-bit packed ops + integrated
layers) exactly as Tab III isolates it from framework overheads.

Networks run at reduced spatial resolution on CPU (the full 224/416
float CNNs take minutes/frame on this host); both engines see the SAME
input, so the ratio is preserved.  ``--full`` runs paper-size inputs.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import bnn_model
from repro.models import paper_nets
from repro.serving import PhoneBitEngine

PAPER_SD855_MS = {  # (TFLite CPU, TFLite CPU-quant, PhoneBit) ms
    "alexnet": (87, 24, 9.8),
    "yolov2-tiny": (306, 88, 22.6),
    "vgg16": (932, 252, 73.8),
}

# Reduced benchmark inputs (same nets, smaller spatial extent).
REDUCED_HW = {"alexnet": 67, "vgg16": 64, "yolov2-tiny": 96}
# AlexNet's 6x6x256 fc6 input requires specific sizes: 67 -> conv1 15
# -> pool 7 -> ... we instead cut the nets at the conv stack for the
# reduced run (the conv stack is >95% of both engines' time).


def _conv_stack(spec):
    """Strip dense layers: benchmark the convolutional body."""
    return [l for l in spec
            if not isinstance(l, (bnn_model.BDense, bnn_model.FloatDense))]


def run(full: bool = False) -> list[dict]:
    """Times three executions of each net:

    * float CNN (the Tab III baseline frameworks' path),
    * BNN engine, ``xor`` mode — the paper's Eqn-1 algorithm.  On a host
      CPU XLA lowers popcount to scalar bit arithmetic, so this mode is
      SLOW here; its target hardware is wide-bitwise-SIMD (the paper's
      mobile GPU / the TPU VPU via the Pallas kernels),
    * BNN engine, ``pm1`` mode — the matmul-engine reformulation
      (cnt = (bits − ±1·dot)/2), which rides the platform's optimized
      GEMM and carries the 32× weight-bandwidth win everywhere.
    """
    rows = []
    rng = np.random.default_rng(0)
    for name in ("alexnet", "yolov2-tiny", "vgg16"):
        spec, (h, w, c) = paper_nets.get(name)
        if not full:
            spec = _conv_stack(spec)
            h = w = REDUCED_HW[name]
        params = bnn_model.init_params(jax.random.key(0), spec)
        x = jnp.asarray(rng.integers(0, 256, (1, h, w, c), dtype=np.uint8))

        engine_xor = PhoneBitEngine.from_trained(params, spec, (h, w),
                                                 matmul_mode="xla")
        t_xor = time_fn(engine_xor, x)
        engine_pm1 = PhoneBitEngine.from_trained(params, spec, (h, w),
                                                 matmul_mode="xla_pm1")
        t_pm1 = time_fn(engine_pm1, x)
        float_fwd = jax.jit(
            lambda p, xx: paper_nets.cnn_float_forward(p, spec, xx))
        t_float = time_fn(float_fwd, params, x)

        tfl_cpu, tfl_q, pb = PAPER_SD855_MS[name]
        # Hardware-transferable bounds: the technique's win is 32× fewer
        # weight/activation bytes and 32× fewer reduction ops per SIMD
        # lane (one int32 word = 32 MACs).  Wall-clock follows whichever
        # bound the platform exposes; this host CPU exposes neither
        # (XLA popcount = scalar bit math, see module docstring), the
        # paper's mobile GPU and the TPU VPU kernels expose both.
        from repro.core import converter
        packed = converter.convert(params, spec, (h, w))
        wb_float = converter.float_model_bytes(params)
        wb_bnn = converter.model_bytes(packed)
        rows.append(dict(
            network=name, input=f"{h}x{w}",
            float_ms=round(t_float * 1e3, 2),
            bnn_xor_ms=round(t_xor * 1e3, 2),
            bnn_pm1_ms=round(t_pm1 * 1e3, 2),
            host_speedup_pm1=round(t_float / t_pm1, 2),
            host_speedup_xor=round(t_float / t_xor, 2),
            bw_bound_speedup=round(wb_float / wb_bnn, 1),
            ops_bound_speedup=32.0,
            paper_speedup_vs_tflite=round(tfl_cpu / pb, 2),
            paper_speedup_vs_tflite_quant=round(tfl_q / pb, 2),
        ))
    emit(rows, "Table III — runtime (ms/frame), float CNN vs BNN engine "
               "(xor = paper Eqn 1, pm1 = matmul reformulation; *_bound = "
               "hardware-transferable roofline ratios)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(full=ap.parse_args().full)
