"""Sustained-load endurance harness (DESIGN.md §11.4).

Where the other serving benchmark measures *how fast*, this one measures
*whether it keeps working*: an open-loop request stream (arrivals on a
fixed schedule, independent of server progress — the arrival process a
real front end sees) is driven through the hardened
:class:`~repro.serving.server.InferenceServer` for long enough that
slow leaks and drift show up, under two scenarios:

* ``steady``    — no faults.  Asserts the boring invariants that make
  sustained serving possible: every request terminally resolves,
  ``engine.trace_count`` stays **flat** after warmup (the zero-retrace
  serving contract), RSS growth after warmup stays under a budget (no
  per-request leak), and the latency SLO attainment is reported.
* ``fault_storm`` — a seeded :class:`~repro.serving.faults.FaultPlan`
  injects transient device faults, a compile failure, preprocess
  errors and latency spikes while the same open-loop stream runs.
  Asserts availability (served / (served + errors)) stays above a
  floor, that demotions are visible in the flight records, and — after
  uninstalling the plan — that a sample of served results is
  **bit-exact** vs the engine's ``cross_check`` oracle: retries and
  backend demotions may change *when* a request is served, never *what*
  it returns.
* ``kill_recover`` — the crash-safety story (DESIGN.md §14.3): a child
  process boots from an AOT artifact, journals a request stream
  through a :class:`~repro.serving.recovery.RequestJournal`, serves
  part of it, then SIGKILLs itself mid-stream.  A second fresh process
  boots from the same artifact + journal, replays every
  journaled-but-unresolved request, and the row reports the recovered
  fraction, the recovery wall time, and that the restarted process
  served with **zero retraces** (artifact boot) — the kill-9 proof the
  journal exists for.

Writes ``BENCH_endurance.json`` (provenance-stamped like every BENCH
artifact).  ``--smoke`` is the CI-sized run; the full run rides
``python -m benchmarks.run``.  ``--phase kill|recover --dir D`` are the
subprocess halves of ``kill_recover`` (driven by the parent run, not by
hand).

    PYTHONPATH=src python -m benchmarks.endurance_bench [--smoke]
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, is_skipped, skipped, write_bench


def rss_bytes() -> int | None:
    """Resident set size via /proc (None off Linux)."""
    try:
        with open("/proc/self/statm") as f:
            return int(f.read().split()[1]) * os.sysconf("SC_PAGE_SIZE")
    except (OSError, ValueError):
        return None


def _make_server(watchdog_s: float | None = 10.0, artifact: str | None = None,
                 journal=None):
    from repro.core import bnn_model
    from repro.serving import InferenceServer, PhoneBitEngine, RetryPolicy

    spec = [bnn_model.BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
            bnn_model.Pool(2, 2),
            bnn_model.BConv(32, 32, kernel=3, stride=1, pad=1),
            bnn_model.Pool(2, 2),
            bnn_model.FloatDense(4 * 4 * 32, 10)]
    params = bnn_model.init_params(jax.random.key(0), spec)
    # Serve one rung above the ladder floor so the storm's demotion
    # path (xla_pm1 → xla) is actually reachable — and bit-exact.
    engine = PhoneBitEngine.from_trained(params, spec, (16, 16),
                                         matmul_mode="xla_pm1")
    server = InferenceServer(
        engine, max_batch=4, max_wait_s=0.0, buckets=(1, 2, 4),
        retry=RetryPolicy(max_attempts=3, backoff_base_s=0.002,
                          backoff_cap_s=0.05),
        max_queue=512, watchdog_s=watchdog_s,
        artifact=artifact, journal=journal)
    return engine, server


def _open_loop(server, payloads: list[np.ndarray], rate_hz: float,
               deadline_s: float | None = None) -> list:
    """Drive an open-loop arrival process: request *i* is submitted at
    ``t0 + i/rate`` regardless of server progress (serving ticks fill
    the gaps), then the queue is drained.  Returns the requests."""
    reqs = []
    t0 = time.monotonic()
    for i, p in enumerate(payloads):
        due = t0 + i / rate_hz
        while time.monotonic() < due:
            server.step()
        reqs.append(server.submit(p, deadline_s=deadline_s))
    server.drain()
    return reqs


def _outcome_counts(reqs: list) -> dict:
    from repro.serving import OUTCOMES

    counts = {o: 0 for o in OUTCOMES}
    for r in reqs:
        counts[r.outcome] += 1
    return counts


def _check_bitexact(engine, server, served: list, sample: int = 8) -> dict:
    """Replay a sample of served requests through the ``cross_check``
    oracle (graph path asserted bit-exact vs the legacy flat walk) and
    compare bit-for-bit: resilience must never corrupt results.

    Two things legitimately vary with *when* a request was served, both
    last-ulp float-epilogue effects that never touch the packed binary
    layers: a demoted request ran a different ladder rung (pm1-family vs
    xor-family dense layers associate differently), and the bucket size
    its batch padded to changes XLA's reduction codegen for the float
    dense layer.  So each sample must be bit-identical to the reference
    of one (mode the server actually served under) × (compiled bucket)
    replay — and the configured mode additionally goes through the full
    ``cross_check`` oracle (graph vs legacy walk) on every sample."""
    modes = {engine.matmul_mode}
    if server.health is not None:
        modes.add(server.health.mode)
        for d in server.health.demotions:
            modes.update((d["from_mode"], d["to_mode"]))
    idx = np.linspace(0, len(served) - 1,
                      min(sample, len(served))).astype(int)
    checked = mismatches = 0
    for i in sorted(set(idx.tolist())):
        r = served[i]
        x1 = np.asarray(r.payload)
        engine.cross_check(x1[None])        # oracle: graph == legacy
        got = np.asarray(r.result)
        ok = False
        for m in sorted(modes):
            for b in server.scheduler.buckets:
                xb = np.zeros((b, *x1.shape), x1.dtype)
                xb[0] = x1
                want = np.asarray(engine.compile(b, mode=m)(xb))[0]
                if np.array_equal(got, want):
                    ok = True
                    break
            if ok:
                break
        checked += 1
        mismatches += not ok
    return {"checked": checked, "mismatches": int(mismatches),
            "modes": sorted(modes), "ok": mismatches == 0}


def _run_scenario(name: str, *, requests: int, rate_hz: float,
                  warmup: int, slo_ms: float, rss_budget_mb: float,
                  plan=None) -> dict:
    """One endurance scenario; never lets a serving failure escape —
    any exception that does is the exact bug this harness exists to
    catch, so it is counted, not masked."""
    from repro.obs import metrics as _obs_metrics
    from repro.serving import faults

    engine, server = _make_server()
    rng = np.random.default_rng(42)
    mk = lambda n: [rng.integers(0, 256, (16, 16, 3), dtype=np.uint8)
                    for _ in range(n)]

    server.compile_buckets()
    unhandled = 0
    with _obs_metrics.use_registry() as reg:
        # Warmup outside the measurement window: first-touch allocations
        # (numpy pools, jit dispatch caches) are not leaks.
        try:
            _open_loop(server, mk(warmup), rate_hz)
        except Exception:               # noqa: BLE001 — the bug we hunt
            unhandled += 1
        rss0, trace0 = rss_bytes(), engine.trace_count

        if plan is not None:
            faults.install(plan)
        t_start = time.monotonic()
        try:
            reqs = _open_loop(server, mk(requests), rate_hz)
        except Exception:               # noqa: BLE001
            unhandled += 1
            reqs = []
        finally:
            wall_s = time.monotonic() - t_start
            if plan is not None:
                faults.uninstall()

        rss1, trace1 = rss_bytes(), engine.trace_count
        injected = reg.snapshot().get("faults.injected", 0)

    counts = _outcome_counts(reqs) if reqs else {}
    terminal = all(r.done and r.outcome is not None for r in reqs)
    served = [r for r in reqs if r.outcome == "served"]
    n_err = counts.get("error", 0)
    availability = (len(served) / (len(served) + n_err)
                    if served or n_err else skipped("no samples"))
    m = server.metrics()
    slo_attained = (sum(1 for v in server._metrics.latencies
                        if v * 1e3 <= slo_ms)
                    / len(server._metrics.latencies)
                    if server._metrics.latencies
                    else skipped("no latency samples"))
    rss_growth = (rss1 - rss0) if rss0 is not None and rss1 is not None \
        else skipped("no /proc rss")
    # BackendHealth's own log is authoritative — the flight ring evicts
    # demotion rows once enough request rows follow them.
    demotion_rows = (list(server.health.demotions)
                     if server.health is not None else [])
    row = {
        "scenario": name,
        "requests": requests,
        "rate_hz": rate_hz,
        "wall_s": wall_s,
        "unhandled_exceptions": unhandled,
        "all_terminal": terminal,
        "outcomes": counts,
        "availability": availability,
        "p50_ms": m["p50_ms"], "p95_ms": m["p95_ms"],
        "slo_ms": slo_ms, "slo_attainment": slo_attained,
        "throughput": m["throughput"],
        "retries": m["retries"], "errors": m["errors"],
        "rejected": m["rejected"], "degraded": m["degraded"],
        "mode_final": m["mode"],
        "faults_injected": int(injected or 0),
        "trace_count": {"start": trace0, "end": trace1,
                        "flat": trace1 == trace0},
        "rss": {"start_bytes": rss0, "end_bytes": rss1,
                "growth_bytes": rss_growth,
                "budget_mb": rss_budget_mb,
                "flat": (is_skipped(rss_growth)
                         or rss_growth <= rss_budget_mb * 2**20)},
        "demotions": demotion_rows,
        "bitexact": (_check_bitexact(engine, server, served) if served
                     else {"checked": 0, "mismatches": 0, "ok": False}),
    }
    return row


def _storm_plan():
    """The seeded fault storm: two guaranteed early device faults (a
    deterministic demotion), then rate-based transient noise, one
    compile failure, sparse preprocess errors and latency spikes."""
    from repro.serving.faults import LATENCY_SPIKE, FaultPlan, FaultSpec

    return FaultPlan([
        # Pinned to one bucket: health ladders are per-bucket now
        # (DESIGN.md §14.3), so the guaranteed demotion needs both
        # guaranteed faults to land on the SAME ladder.
        FaultSpec("server.device", "device_fault", times=2,
                  match={"bucket": 4}),
        FaultSpec("server.device", "device_fault", rate=0.05, after=2),
        FaultSpec("executor.call", "device_oom", rate=0.03),
        FaultSpec("engine.compile", "compile_error", times=1, after=1),
        FaultSpec("server.preprocess", "preprocess_error", rate=0.02),
        FaultSpec("server.device", LATENCY_SPIKE, rate=0.05,
                  duration_s=0.002),
    ], seed=7)


def _phase_kill(d: str) -> None:
    """Child half of ``kill_recover``: boot from the artifact, journal
    a request stream, serve a prefix of it, then SIGKILL ourselves with
    requests still unresolved — no atexit, no flush, no goodbye."""
    import signal

    from repro.serving.recovery import RequestJournal

    _engine, server = _make_server(
        artifact=os.path.join(d, "artifact"),
        journal=RequestJournal(os.path.join(d, "journal.jsonl")))
    rng = np.random.default_rng(7)
    for _ in range(24):
        server.submit(rng.integers(0, 256, (16, 16, 3), dtype=np.uint8))
    for _ in range(6):          # resolve a prefix of the stream
        server.step(force=True)
    os.kill(os.getpid(), signal.SIGKILL)


def _phase_recover(d: str) -> None:
    """Restart half of ``kill_recover``: a fresh process boots from the
    same artifact + journal, replays every journaled-but-unresolved
    request, and reports what it recovered (JSON on stdout)."""
    import json

    from repro.serving.recovery import RequestJournal, replay_journal

    t0 = time.monotonic()
    jpath = os.path.join(d, "journal.jsonl")
    pre = RequestJournal.scan(jpath)
    engine, server = _make_server(
        artifact=os.path.join(d, "artifact"),
        journal=RequestJournal(jpath))
    reqs = replay_journal(server, jpath)
    server.drain()
    recovery_s = time.monotonic() - t0
    post = RequestJournal.scan(jpath)
    print(json.dumps({
        "journaled_unresolved": len(pre.unresolved),
        "torn_tail": pre.torn_tail,
        "replayed": len(reqs),
        "recovered": sum(1 for r in reqs if r.outcome == "served"),
        "outcomes": _outcome_counts(reqs),
        "unresolved_after": len(post.unresolved),
        "trace_count": engine.trace_count,
        "recovery_s": recovery_s,
    }))


def _kill_recover_scenario(smoke: bool) -> dict:
    """Drive the two subprocess phases and assemble the row.  A spawn
    environment that cannot run subprocesses yields a skipped row, not
    a crash (the CI job asserts the row is NOT skipped)."""
    import json
    import subprocess
    import sys
    import tempfile

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    d = tempfile.mkdtemp(prefix="endurance_killrec_")
    engine, _server = _make_server()
    engine.export_artifact(os.path.join(d, "artifact"), buckets=(1, 2, 4))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root,
         *filter(None, [env.get("PYTHONPATH")])])
    env["REPRO_AUTOTUNE_CACHE"] = "0"

    def phase(name: str, timeout: float):
        return subprocess.run(
            [sys.executable, "-m", "benchmarks.endurance_bench",
             "--phase", name, "--dir", d],
            capture_output=True, text=True, timeout=timeout, env=env,
            cwd=root)

    row: dict = {"scenario": "kill_recover", "requests": 24}
    try:
        p_kill = phase("kill", 420)
    except Exception as e:              # noqa: BLE001 — report, don't crash
        row["ok"] = skipped(f"kill phase spawn failed: {e}")
        return row
    killed = p_kill.returncode == -9
    row["killed"] = killed
    if not killed:
        row["ok"] = False
        row["error"] = (f"kill phase exited {p_kill.returncode}: "
                        f"{p_kill.stderr[-500:]}")
        return row
    try:
        p_rec = phase("recover", 420)
        rec = json.loads(p_rec.stdout.strip().splitlines()[-1])
    except Exception as e:              # noqa: BLE001
        row["ok"] = False
        row["error"] = f"recover phase failed: {e}"
        return row
    row.update(rec)
    # The §14.3 contract: every journaled-unresolved request is
    # replayed and terminally resolved, the restarted process serves
    # with zero retraces (artifact boot), and nothing stays open.
    row["recovered_fraction"] = (
        rec["recovered"] / rec["journaled_unresolved"]
        if rec["journaled_unresolved"] else 1.0)
    row["ok"] = (rec["replayed"] == rec["journaled_unresolved"]
                 and rec["recovered"] == rec["journaled_unresolved"]
                 and rec["unresolved_after"] == 0
                 and rec["trace_count"] == 0)
    return row


def run(smoke: bool = False, out: str = "BENCH_endurance.json") -> dict:
    n = 64 if smoke else 500
    rate = 400.0 if smoke else 250.0
    scenarios = [
        _run_scenario("steady", requests=n, rate_hz=rate,
                      warmup=16, slo_ms=250.0, rss_budget_mb=64.0),
        _run_scenario("fault_storm", requests=n, rate_hz=rate,
                      warmup=16, slo_ms=500.0, rss_budget_mb=64.0,
                      plan=_storm_plan()),
        _kill_recover_scenario(smoke),
    ]
    steady = scenarios[0]
    storm = scenarios[1]
    killrec = scenarios[2]
    loop = scenarios[:2]                # the open-loop rows
    summary = {
        "unhandled_exceptions": sum(s["unhandled_exceptions"]
                                    for s in loop),
        "all_terminal": all(s["all_terminal"] for s in loop),
        "steady_flat_trace": steady["trace_count"]["flat"],
        "steady_flat_rss": steady["rss"]["flat"],
        "storm_availability": storm["availability"],
        "storm_availability_floor": 0.95,
        "storm_demotions": len(storm["demotions"]),
        "bitexact_ok": all(s["bitexact"]["ok"] for s in loop),
        "kill_recover_ok": killrec["ok"],
        "kill_recovered_fraction": killrec.get("recovered_fraction"),
        "kill_recovery_s": killrec.get("recovery_s"),
        "ok": (
            sum(s["unhandled_exceptions"] for s in loop) == 0
            and all(s["all_terminal"] for s in loop)
            and steady["trace_count"]["flat"]
            and steady["rss"]["flat"]
            and (storm["availability"]
                 if isinstance(storm["availability"], float) else 0) >= 0.95
            and all(s["bitexact"]["ok"] for s in loop)
            and killrec["ok"] is True
        ),
    }
    report = {
        "device": f"{jax.default_backend()}:"
                  f"{jax.devices()[0].device_kind}",
        "smoke": smoke,
        "scenarios": scenarios,
        "summary": summary,
    }
    report = write_bench(out, report)

    emit([{
        "scenario": s["scenario"], "req": s["requests"],
        "served": s["outcomes"].get("served", ""),
        "errors": s["errors"], "retries": s["retries"],
        "avail": (f"{s['availability']:.3f}"
                  if isinstance(s["availability"], float) else ""),
        "p95_ms": (f"{s['p95_ms']:.1f}"
                   if s["p95_ms"] is not None else ""),
        "flat_trace": s["trace_count"]["flat"],
        "rss_mb": (f"{s['rss']['growth_bytes'] / 2**20:.1f}"
                   if isinstance(s["rss"]["growth_bytes"], int) else ""),
        "demotions": len(s["demotions"]),
        "bitexact": s["bitexact"]["ok"],
    } for s in loop], "§Endurance: sustained load + fault storm")
    emit([{
        "scenario": killrec["scenario"], "req": killrec.get("requests"),
        "killed": killrec.get("killed", ""),
        "journaled": killrec.get("journaled_unresolved", ""),
        "recovered": killrec.get("recovered", ""),
        "fraction": (f"{killrec['recovered_fraction']:.2f}"
                     if isinstance(killrec.get("recovered_fraction"),
                                   float) else ""),
        "traces": killrec.get("trace_count", ""),
        "recovery_s": (f"{killrec['recovery_s']:.1f}"
                       if isinstance(killrec.get("recovery_s"), float)
                       else ""),
        "ok": killrec["ok"],
    }], "§Endurance: kill -9 → artifact + journal restart")
    print(f"wrote {out} (ok={summary['ok']}, storm availability="
          f"{summary['storm_availability']}, kill_recover="
          f"{summary['kill_recover_ok']})")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.endurance_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; still writes BENCH_endurance.json")
    ap.add_argument("--phase", choices=("kill", "recover"),
                    help="subprocess halves of kill_recover (internal)")
    ap.add_argument("--dir", dest="dir_",
                    help="shared artifact+journal dir for --phase")
    args = ap.parse_args(argv)
    if args.phase:
        if not args.dir_:
            ap.error("--phase requires --dir")
        (_phase_kill if args.phase == "kill" else _phase_recover)(args.dir_)
        return
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
