"""Per-kernel microbenchmarks → machine-readable ``BENCH_kernels.json``.

Three comparisons per fig5 (YOLOv2-Tiny) binary conv layer, all bit-exact
by construction, so the deltas are pure execution-engine effects:

* **reduction**: the whole-tile vectorized xor+popcount reduction
  (``reduction="vector"``) vs the historical per-word
  ``fori_loop``+``dynamic_slice`` form (``reduction="loop"``) inside
  ``xnor_popcount_matmul``, on the layer's im2col matmul shape.
* **conv path**: the direct (im2col-free) fused kernel vs the im2col
  fused kernel on the layer's conv shape.
* **chain**: the megakernel region starting at the layer (the layer's
  conv+pool plus the *next* graph node, DESIGN.md §9) as one Pallas call
  with VMEM-resident intermediates, vs the per-node ``vpu_direct`` path
  (direct kernel per conv, packed OR-pool between) — plus the HBM bytes
  the fusion avoids at each interior boundary.

Plus one **packing** row: the first-layer bit-plane split+pack kernel
(``bitplane_pack``) at conv1's input shape, so packing perf is tracked
alongside the conv kernels.

The JSON artifact records per-kernel latency, effective GB/s and the
backend winner so the perf trajectory is tracked across PRs (every run
overwrites ``BENCH_kernels.json`` at the repo root; CI's ``--smoke`` run
shrinks shapes but keeps the schema identical).

Off-TPU both Pallas kernels execute in ``interpret`` mode — absolute
numbers are then validator-grade only, but the loop/vector and
direct/im2col *ratios* still track the amount of work each form issues.
Shapes are scaled down (channel dims exact, spatial dims capped) to keep
interpret-mode timings tractable.
"""

from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench
from benchmarks.timing import time_stable as _time_stable
from repro.core import binary_conv, layer_integration, packing
from repro.core.bnn_model import BConv, Pool
from repro.core.packing import num_words
from repro.kernels import ops as kops
from repro.kernels.chain_conv import StageSpec
from repro.kernels.direct_conv_bn_binarize import direct_conv_bn_binarize
from repro.runtime.regions import stages_hbm_bytes_avoided
from repro.kernels.xnor_popcount_matmul import xnor_popcount_matmul
from repro.models import paper_nets

BENCH_PATH = pathlib.Path("BENCH_kernels.json")

# Spatial grid entering each conv at full 416 res (fig5_layers), capped to
# keep interpret-mode popcount loops tractable on the host.
_SIZES = [416, 208, 104, 52, 26, 13, 13, 13]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def _bench_layer(layer: BConv, h: int, m_red: int, rng,
                 iters: int) -> dict:
    """One fig5 conv layer: reduction + conv-path comparison."""
    kk, c_in, c_out = layer.kernel, layer.c_in, layer.c_out
    x = jnp.asarray(rng.choice([-1.0, 1.0],
                               (1, h, h, c_in)).astype(np.float32))
    w = jnp.asarray(rng.choice([-1.0, 1.0],
                               (kk, kk, c_in, c_out)).astype(np.float32))
    xp = packing.pack_signs(x, axis=-1)
    wp = binary_conv.pack_conv_weights(w)
    kv = kk * kk * c_in
    t = jnp.asarray(rng.integers(0, kv, c_out), jnp.int32)
    s = jnp.asarray(rng.integers(0, 2, c_out).astype(bool))
    p = layer_integration.IntegratedParams(t, s)
    interp = _interpret()

    # -- reduction comparison on the layer's im2col matmul shape ----------
    # m_red rows ≈ the layer's OH*OW at benchmark resolution — enough rows
    # to amortize per-block overhead so the loop/vector delta is resolvable
    # above host-timing noise.
    m, wdim = m_red, wp.shape[1]
    flat = jnp.asarray(
        rng.integers(-2**31, 2**31, (m, wdim), dtype=np.int64)
        .astype(np.int32))
    nbytes = 4 * (m * wdim + c_out * wdim + m * c_out)
    budget = 0.15 if iters == 1 else 0.3
    times = {}
    for red in ("vector", "loop"):
        f = lambda a, b: xnor_popcount_matmul(a, b, reduction=red,
                                              interpret=interp)
        times[red] = _time_stable(f, flat, wp, budget_s=budget)
    red_winner = min(times, key=times.get)

    # -- conv path: direct (im2col-free) vs im2col fused ------------------
    conv_times = {}
    conv_times["vpu_direct"] = _time_stable(
        lambda xx, ww: direct_conv_bn_binarize(
            xx, ww, t, s, kh=kk, kw=kk, stride=layer.stride, pad=layer.pad,
            interpret=interp),
        xp, wp, budget_s=budget, warmup=1)
    conv_times["vpu_popcount"] = _time_stable(
        lambda xx, ww: kops.fused_binary_conv2d(
            xx, ww, p, kk, kk, layer.stride, layer.pad,
            mode="vpu_popcount"),
        xp, wp, budget_s=budget, warmup=1)
    conv_winner = min(conv_times, key=conv_times.get)
    # Traffic of the conv that was actually timed (shape n=1, h x h):
    # direct reads the input once + filters and stores packed output;
    # im2col additionally materializes the (OH*OW, KH*KW*Cw) patch tensor.
    oh = binary_conv.conv_out_size(h, kk, layer.stride, layer.pad)
    m_conv = oh * oh
    out_words = m_conv * (-(-c_out // 32))
    direct_bytes = 4 * (xp.size + wp.size + out_words)
    im2col_bytes = 4 * (xp.size + 2 * m_conv * wdim + wp.size + out_words)

    return dict(
        grid=h, c_in=c_in, c_out=c_out, kernel=kk,
        matmul_shape=[int(m), int(c_out), int(wdim)],
        conv_positions=int(m_conv),
        reduction=dict(
            loop_ms=round(times["loop"] * 1e3, 3),
            vector_ms=round(times["vector"] * 1e3, 3),
            vector_speedup=round(times["loop"] / max(times["vector"],
                                                     1e-12), 2),
            vector_gbps=round(_gbps(nbytes, times["vector"]), 4),
            winner=red_winner),
        conv=dict(
            im2col_ms=round(conv_times["vpu_popcount"] * 1e3, 3),
            direct_ms=round(conv_times["vpu_direct"] * 1e3, 3),
            direct_speedup=round(
                conv_times["vpu_popcount"]
                / max(conv_times["vpu_direct"], 1e-12), 2),
            direct_gbps=round(
                _gbps(direct_bytes, conv_times["vpu_direct"]), 4),
            im2col_gbps=round(
                _gbps(im2col_bytes, conv_times["vpu_popcount"]), 4),
            patch_bytes_avoided=int(im2col_bytes - direct_bytes),
            winner=conv_winner),
    )


def _synth_conv(layer: BConv, rng):
    """Synthetic packed weights + integer epilogue for one conv layer."""
    kk = layer.kernel
    w = jnp.asarray(rng.choice([-1.0, 1.0],
                               (kk, kk, layer.c_in, layer.c_out))
                    .astype(np.float32))
    wp = binary_conv.pack_conv_weights(w)
    t = jnp.asarray(rng.integers(0, kk * kk * layer.c_in, layer.c_out),
                    jnp.int32)
    s = jnp.asarray(rng.integers(0, 2, layer.c_out).astype(bool))
    return wp, layer_integration.IntegratedParams(t, s)


def _bench_chain_row(span: list[tuple[BConv, Pool | None]], h: int, rng,
                     budget: float) -> dict:
    """Megakernel region vs the per-node ``vpu_direct`` path over the same
    span: each conv(+pool) graph node plus its successor, one Pallas call
    (intermediates in the VMEM arena) vs one direct kernel per conv with
    the packed OR-pool between (every boundary through HBM).  Both paths
    are asserted bit-exact before timing."""
    c_in = span[0][0].c_in
    x = jnp.asarray(packing.pack_signs(
        jnp.asarray(rng.choice([-1.0, 1.0], (1, h, h, c_in))
                    .astype(np.float32)), axis=-1))

    stages: list[StageSpec] = []
    arrays: list = []
    pernode_ops: list = []
    for conv, pool in span:
        wp, p = _synth_conv(conv, rng)
        stages.append(StageSpec("conv", conv.kernel, conv.stride,
                                conv.pad, conv.pad, channels=conv.c_out))
        arrays += [wp, None, p.threshold, p.sign_flip]
        pernode_ops.append(("conv", wp, p, conv))
        if pool is not None:
            stages.append(StageSpec("pool", pool.window, pool.stride,
                                    pool.pad[0], pool.pad[1],
                                    channels=conv.c_out))
            pernode_ops.append(("pool", pool))

    stages_t, arrays_t = tuple(stages), tuple(arrays)

    @jax.jit
    def pernode(xx):
        y = xx
        for op in pernode_ops:
            if op[0] == "conv":
                _, wp, p, conv = op
                y = kops.fused_binary_conv2d(
                    y, wp, p, conv.kernel, conv.kernel, conv.stride,
                    conv.pad, mode="vpu_direct")
            else:
                pool = op[1]
                y = binary_conv.binary_or_maxpool(y, pool.window,
                                                  pool.stride,
                                                  pad=tuple(pool.pad))
        return y

    chain = jax.jit(lambda xx: kops.chain_forward(xx, stages_t, arrays_t))
    np.testing.assert_array_equal(np.asarray(chain(x)),
                                  np.asarray(pernode(x)))

    t_chain = _time_stable(chain, x, budget_s=budget, warmup=1)
    t_node = _time_stable(pernode, x, budget_s=budget, warmup=1)

    # HBM traffic the fusion avoids, via the canonical accounting shared
    # with graph_plan's region report.
    avoided = stages_hbm_bytes_avoided(stages_t,
                                       (1, h, h, num_words(c_in)))

    return dict(
        span="+".join(f"{c.c_in}>{c.c_out}" + ("p" if p else "")
                      for c, p in span),
        n_stages=len(stages_t),
        chain_ms=round(t_chain * 1e3, 3),
        pernode_ms=round(t_node * 1e3, 3),
        chain_speedup=round(t_node / max(t_chain, 1e-12), 2),
        hbm_bytes_avoided=int(avoided),
        winner="vpu_chain" if t_chain < t_node else "vpu_direct")


def _bench_packing(h: int, rng, budget: float) -> dict:
    """First-layer bit-plane split + channel pack at conv1's input shape."""
    from repro.core.bitplanes import NUM_PLANES

    x = jnp.asarray(rng.integers(0, 256, (1, h, h, 3)), jnp.uint8)
    f = jax.jit(lambda xx: kops.bitplane_pack(xx))
    t = _time_stable(f, x, budget_s=budget, warmup=1)
    nbytes = int(x.size) + 4 * h * h * NUM_PLANES * num_words(3)
    return dict(grid=h, c_in=3,
                pack_ms=round(t * 1e3, 3),
                gbps=round(_gbps(nbytes, t), 4))


def run(smoke: bool = False, path: pathlib.Path | None = None) -> dict:
    spec, _ = paper_nets.get("yolov2-tiny")
    convs: list[tuple[BConv, Pool | None]] = []
    for j, l in enumerate(spec):
        if isinstance(l, BConv):
            nxt = spec[j + 1] if j + 1 < len(spec) else None
            convs.append((l, nxt if isinstance(nxt, Pool) else None))
    scale, cap, m_cap = (52, 4, 1024) if smoke else (16, 13, 4096)
    iters = 1 if smoke else 5
    budget = 0.15 if smoke else 0.3
    rng = np.random.default_rng(0)

    layers = {}
    for i, ((layer, pool), size) in enumerate(zip(convs, _SIZES), start=1):
        if layer.first:
            continue  # conv1 rides the bit-plane path; not a like-for-like
        h = min(max(size // scale, 4), cap)
        m_red = min(max((size // 4) ** 2, 169), m_cap)
        row = _bench_layer(layer, h, m_red, rng, iters)
        # Chain row: this graph node plus its successor (the last conv
        # spans nothing and runs as a single-stage region — no interior
        # boundary, so no HBM win is claimed for it).
        span = convs[i - 1:i + 1]
        row["chain"] = _bench_chain_row(span, h, rng, budget)
        layers[f"conv{i}"] = row

    pack_h = min(max(_SIZES[0] // scale, 4), cap * 2)
    packing_row = _bench_packing(pack_h, rng, budget)

    report = dict(
        schema="bench-kernels-v2",
        device_kind=jax.default_backend(),
        pallas_interpret=_interpret(),
        smoke=smoke,
        layers=layers,
        packing=packing_row,
        summary=dict(
            vector_wins=sum(r["reduction"]["winner"] == "vector"
                            for r in layers.values()),
            direct_wins=sum(r["conv"]["winner"] == "vpu_direct"
                            for r in layers.values()),
            chain_wins=sum(r["chain"]["winner"] == "vpu_chain"
                           for r in layers.values()),
            hbm_bytes_avoided=sum(r["chain"]["hbm_bytes_avoided"]
                                  for r in layers.values()),
            n_layers=len(layers)),
    )
    out = path or BENCH_PATH
    report = write_bench(out, report, sort_keys=True)
    s = report["summary"]
    print(f"# §Kernels — wrote {out} "
          f"({s['vector_wins']}/{len(layers)} layers: vectorized "
          f"reduction wins; {s['direct_wins']}/{len(layers)}: direct conv "
          f"wins; {s['chain_wins']}/{len(layers)}: chain wins, "
          f"{s['hbm_bytes_avoided']} HBM bytes avoided; packing "
          f"{packing_row['pack_ms']}ms @ grid {packing_row['grid']})")
    return report


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.kernels_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes; still writes "
                         "BENCH_kernels.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
