"""Per-kernel microbenchmarks → machine-readable ``BENCH_kernels.json``.

Two comparisons per fig5 (YOLOv2-Tiny) binary conv layer, both bit-exact
by construction, so the deltas are pure execution-engine effects:

* **reduction**: the whole-tile vectorized xor+popcount reduction
  (``reduction="vector"``) vs the historical per-word
  ``fori_loop``+``dynamic_slice`` form (``reduction="loop"``) inside
  ``xnor_popcount_matmul``, on the layer's im2col matmul shape.
* **conv path**: the direct (im2col-free) fused kernel vs the im2col
  fused kernel on the layer's conv shape.

The JSON artifact records per-kernel latency, effective GB/s and the
backend winner so the perf trajectory is tracked across PRs (every run
overwrites ``BENCH_kernels.json`` at the repo root; CI's ``--smoke`` run
shrinks shapes but keeps the schema identical).

Off-TPU both Pallas kernels execute in ``interpret`` mode — absolute
numbers are then validator-grade only, but the loop/vector and
direct/im2col *ratios* still track the amount of work each form issues.
Shapes are scaled down (channel dims exact, spatial dims capped) to keep
interpret-mode timings tractable.
"""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binary_conv, layer_integration, packing
from repro.core.bnn_model import BConv
from repro.kernels import ops as kops
from repro.kernels.direct_conv_bn_binarize import direct_conv_bn_binarize
from repro.kernels.xnor_popcount_matmul import xnor_popcount_matmul
from repro.models import paper_nets

BENCH_PATH = pathlib.Path("BENCH_kernels.json")

# Spatial grid entering each conv at full 416 res (fig5_layers), capped to
# keep interpret-mode popcount loops tractable on the host.
_SIZES = [416, 208, 104, 52, 26, 13, 13, 13]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def _time_stable(fn, *args, budget_s: float = 0.3, max_iters: int = 24,
                 warmup: int = 2) -> float:
    """Minimum wall seconds per call, repeating until a time budget is
    spent.  Min (not median) is the noise-robust microbenchmark estimator
    on a shared host: external interference only ever adds time."""
    import time as _time

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best, spent, it = float("inf"), 0.0, 0
    while spent < budget_s and it < max_iters:
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = _time.perf_counter() - t0
        best, spent, it = min(best, dt), spent + dt, it + 1
    return best


def _bench_layer(layer: BConv, h: int, m_red: int, rng,
                 iters: int) -> dict:
    """One fig5 conv layer: reduction + conv-path comparison."""
    kk, c_in, c_out = layer.kernel, layer.c_in, layer.c_out
    x = jnp.asarray(rng.choice([-1.0, 1.0],
                               (1, h, h, c_in)).astype(np.float32))
    w = jnp.asarray(rng.choice([-1.0, 1.0],
                               (kk, kk, c_in, c_out)).astype(np.float32))
    xp = packing.pack_signs(x, axis=-1)
    wp = binary_conv.pack_conv_weights(w)
    kv = kk * kk * c_in
    t = jnp.asarray(rng.integers(0, kv, c_out), jnp.int32)
    s = jnp.asarray(rng.integers(0, 2, c_out).astype(bool))
    p = layer_integration.IntegratedParams(t, s)
    interp = _interpret()

    # -- reduction comparison on the layer's im2col matmul shape ----------
    # m_red rows ≈ the layer's OH*OW at benchmark resolution — enough rows
    # to amortize per-block overhead so the loop/vector delta is resolvable
    # above host-timing noise.
    m, wdim = m_red, wp.shape[1]
    flat = jnp.asarray(
        rng.integers(-2**31, 2**31, (m, wdim), dtype=np.int64)
        .astype(np.int32))
    nbytes = 4 * (m * wdim + c_out * wdim + m * c_out)
    budget = 0.15 if iters == 1 else 0.3
    times = {}
    for red in ("vector", "loop"):
        f = lambda a, b: xnor_popcount_matmul(a, b, reduction=red,
                                              interpret=interp)
        times[red] = _time_stable(f, flat, wp, budget_s=budget)
    red_winner = min(times, key=times.get)

    # -- conv path: direct (im2col-free) vs im2col fused ------------------
    conv_times = {}
    conv_times["vpu_direct"] = _time_stable(
        lambda xx, ww: direct_conv_bn_binarize(
            xx, ww, t, s, kh=kk, kw=kk, stride=layer.stride, pad=layer.pad,
            interpret=interp),
        xp, wp, budget_s=budget, warmup=1)
    conv_times["vpu_popcount"] = _time_stable(
        lambda xx, ww: kops.fused_binary_conv2d(
            xx, ww, p, kk, kk, layer.stride, layer.pad,
            mode="vpu_popcount"),
        xp, wp, budget_s=budget, warmup=1)
    conv_winner = min(conv_times, key=conv_times.get)
    # Traffic of the conv that was actually timed (shape n=1, h x h):
    # direct reads the input once + filters and stores packed output;
    # im2col additionally materializes the (OH*OW, KH*KW*Cw) patch tensor.
    oh = binary_conv.conv_out_size(h, kk, layer.stride, layer.pad)
    m_conv = oh * oh
    out_words = m_conv * (-(-c_out // 32))
    direct_bytes = 4 * (xp.size + wp.size + out_words)
    im2col_bytes = 4 * (xp.size + 2 * m_conv * wdim + wp.size + out_words)

    return dict(
        grid=h, c_in=c_in, c_out=c_out, kernel=kk,
        matmul_shape=[int(m), int(c_out), int(wdim)],
        conv_positions=int(m_conv),
        reduction=dict(
            loop_ms=round(times["loop"] * 1e3, 3),
            vector_ms=round(times["vector"] * 1e3, 3),
            vector_speedup=round(times["loop"] / max(times["vector"],
                                                     1e-12), 2),
            vector_gbps=round(_gbps(nbytes, times["vector"]), 4),
            winner=red_winner),
        conv=dict(
            im2col_ms=round(conv_times["vpu_popcount"] * 1e3, 3),
            direct_ms=round(conv_times["vpu_direct"] * 1e3, 3),
            direct_speedup=round(
                conv_times["vpu_popcount"]
                / max(conv_times["vpu_direct"], 1e-12), 2),
            direct_gbps=round(
                _gbps(direct_bytes, conv_times["vpu_direct"]), 4),
            im2col_gbps=round(
                _gbps(im2col_bytes, conv_times["vpu_popcount"]), 4),
            patch_bytes_avoided=int(im2col_bytes - direct_bytes),
            winner=conv_winner),
    )


def run(smoke: bool = False, path: pathlib.Path | None = None) -> dict:
    spec, _ = paper_nets.get("yolov2-tiny")
    convs = [l for l in spec if isinstance(l, BConv)]
    scale, cap, m_cap = (52, 4, 1024) if smoke else (16, 13, 4096)
    iters = 1 if smoke else 5
    rng = np.random.default_rng(0)

    layers = {}
    for i, (layer, size) in enumerate(zip(convs, _SIZES), start=1):
        if layer.first:
            continue  # conv1 rides the bit-plane path; not a like-for-like
        h = min(max(size // scale, 4), cap)
        m_red = min(max((size // 4) ** 2, 169), m_cap)
        layers[f"conv{i}"] = _bench_layer(layer, h, m_red, rng, iters)

    report = dict(
        schema="bench-kernels-v1",
        device_kind=jax.default_backend(),
        pallas_interpret=_interpret(),
        smoke=smoke,
        layers=layers,
        summary=dict(
            vector_wins=sum(r["reduction"]["winner"] == "vector"
                            for r in layers.values()),
            direct_wins=sum(r["conv"]["winner"] == "vpu_direct"
                            for r in layers.values()),
            n_layers=len(layers)),
    )
    out = path or BENCH_PATH
    out.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    print(f"# §Kernels — wrote {out} "
          f"({report['summary']['vector_wins']}/{len(layers)} layers: "
          f"vectorized reduction wins; "
          f"{report['summary']['direct_wins']}/{len(layers)}: direct conv "
          f"wins)")
    return report


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.kernels_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized shapes; still writes "
                         "BENCH_kernels.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
