"""Cold-start benchmark: boot-to-first-response across boot modes.

The PR-headline number for the AOT artifact subsystem (DESIGN.md §12):
how long a *fresh process* takes from server construction to its first
served response, under three boot modes —

* **cold**         empty autotune cache, no artifact: full trace + XLA
                   compile + autotune sweep on the serve path;
* **autotune-warm** the disk winner table is populated (a prior run),
                   but executables still trace + compile live;
* **artifact-warm** ``InferenceServer(artifact=...)``: executables are
                   deserialized from the AOT artifact — zero traces.

Each boot runs in a **subprocess** (``--child``) so the measurement is
an honest process boot: nothing cached in the parent can leak in.  The
boot window opens at server construction and closes at the first served
result.  Excluded from the window (and reported separately): python/jax
import time and the engine/model build (bit-packing + graph planning) —
costs every boot mode pays identically and that no executable artifact
can remove, since weights stay live operands of the frozen executable.

A fourth, in-process row exercises the multi-tenant path: two workloads
behind one :class:`~repro.serving.multiplex.MultiTenantServer` at 3:1
weights, reporting the dispatched-row split over a backlogged window
against the configured share.

Writes ``BENCH_coldstart.json``; the acceptance gate is artifact-warm
boot >= 5x faster than cold on every measured workload with
``trace_count == 0`` after load.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

from benchmarks.common import emit, write_bench

_MARKER = "COLDSTART_JSON:"

#: (workload, variant) pairs measured per boot mode.  Tiny variants:
#: the cold/warm delta is compile+tune cost, which the conformance-scale
#: nets already expose without minutes of CPU conv per boot.
WORKLOADS = (("alexnet_imagenet", "tiny"), ("vgg16_imagenet", "tiny"))


# ---------------------------------------------------------------------------
# child: one measured boot (or one artifact export) in a fresh process
# ---------------------------------------------------------------------------

def _child(args) -> None:
    import numpy as np

    from repro import workloads

    buckets = tuple(int(b) for b in args.buckets.split(","))
    wl = workloads.get(args.workload, variant=args.variant,
                       matmul_mode=args.mode)

    if args.export:
        t0 = time.perf_counter()
        meta = wl.engine.export_artifact(args.export, buckets,
                                         workload=wl.name)
        out = {"export_s": time.perf_counter() - t0,
               "buckets": sorted(int(b) for b in meta["buckets"])}
        print(_MARKER + json.dumps(out), flush=True)
        return

    rng = np.random.default_rng(0)
    img = rng.integers(0, 255, (*wl.input_hw, 3), np.uint8)
    # Model load (bit-packing, layer integration, graph build) happens
    # before the window opens: every boot mode pays it identically and
    # no artifact can remove it — weights are live operands, not part of
    # the frozen executable.  Reported separately for the full picture.
    t_build = time.perf_counter()
    wl.engine
    engine_build_s = time.perf_counter() - t_build
    t0 = time.perf_counter()
    server = wl.server(buckets=buckets, max_batch=max(buckets),
                       max_wait_s=0.0, artifact=args.artifact or None)
    bucket_compile_s = ({} if args.artifact
                        else server.compile_buckets())
    r = server.submit(img)
    server.drain()
    boot_s = time.perf_counter() - t0
    out = {
        "boot_s": boot_s,
        "engine_build_s": engine_build_s,
        "bucket_compile_s": {str(k): v
                             for k, v in bucket_compile_s.items()},
        "outcome": r.outcome,
        "trace_count": wl.engine.trace_count,
        "artifact_report": server.artifact_report,
    }
    print(_MARKER + json.dumps(out), flush=True)


def _run_child(extra: list[str], cache_path: str,
               timeout_s: float = 600.0) -> dict:
    env = dict(os.environ)
    env["REPRO_AUTOTUNE_CACHE"] = cache_path
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "benchmarks.coldstart_bench",
           "--child"] + extra
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout_s, env=env)
    for line in proc.stdout.splitlines():
        if line.startswith(_MARKER):
            return json.loads(line[len(_MARKER):])
    raise RuntimeError(
        f"coldstart child emitted no result (exit {proc.returncode}):\n"
        f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


# ---------------------------------------------------------------------------
# parent: the three boot modes per workload + the multi-tenant row
# ---------------------------------------------------------------------------

def bench_workload(name: str, variant: str, *, mode: str,
                   buckets: tuple[int, ...], keep_dir: str) -> dict:
    base = [f"--workload={name}", f"--variant={variant}",
            f"--mode={mode}",
            "--buckets=" + ",".join(str(b) for b in buckets)]
    cache = os.path.join(keep_dir, f"{name}.autotune.json")
    art = os.path.join(keep_dir, f"{name}.artifact")

    cold = _run_child(base, cache_path=os.path.join(
        keep_dir, f"{name}.coldcache.json"))
    # Populate the shared disk cache, then boot against it.
    _run_child(base, cache_path=cache)
    warm = _run_child(base, cache_path=cache)
    export = _run_child(base + [f"--export={art}"], cache_path=cache)
    # Artifact boot gets an EMPTY autotune cache on purpose: the winner
    # table rides inside the artifact, nothing else may warm it.
    aot = _run_child(base + [f"--artifact={art}"], cache_path=os.path.join(
        keep_dir, f"{name}.aotcache.json"))

    row = {
        "workload": name, "variant": variant, "mode": mode,
        "buckets": list(buckets),
        "cold": cold, "autotune_warm": warm,
        "export": export, "artifact_warm": aot,
        "artifact_speedup": (cold["boot_s"] / aot["boot_s"]
                             if aot["boot_s"] else None),
        "warm_speedup": (cold["boot_s"] / warm["boot_s"]
                         if warm["boot_s"] else None),
    }
    return row


def bench_multitenant(*, requests: int = 16,
                      window_steps: int = 8) -> dict:
    """In-process fairness row: two tiny workloads behind one
    multiplexer at 3:1 weights; the dispatched-row split over a window
    where both lanes stay backlogged is the measured share."""
    import numpy as np

    from repro import workloads
    from repro.serving import MultiTenantServer

    mux = MultiTenantServer(max_wait_s=0.0, buckets=(1, 2), max_batch=2)
    specs = {"alexnet": ("alexnet_imagenet", 3.0),
             "vgg16": ("vgg16_imagenet", 1.0)}
    wls = {}
    for tenant, (wname, weight) in specs.items():
        wls[tenant] = workloads.get(wname, variant="tiny")
        mux.add_workload(tenant, wls[tenant], weight=weight)
    rng = np.random.default_rng(0)
    reqs = {t: [] for t in specs}
    for _ in range(requests):
        for tenant, wl in wls.items():
            img = rng.integers(0, 255, (*wl.input_hw, 3), np.uint8)
            reqs[tenant].append(mux.submit(tenant, img))
    t0 = time.perf_counter()
    for _ in range(window_steps):
        mux.step(force=True)
    window = {t: mux.server(t).dispatched_rows for t in specs}
    mux.drain()
    wall_s = time.perf_counter() - t0
    m = mux.metrics()
    outcomes = {t: {o: sum(1 for r in rs if r.outcome == o)
                    for o in ("served", "error", "shed", "rejected")}
                for t, rs in reqs.items()}
    ratio = (window["alexnet"] / window["vgg16"]
             if window["vgg16"] else None)
    return {
        "tenants": {t: {"workload": specs[t][0], "weight": specs[t][1],
                        "window_rows": window[t],
                        "outcomes": outcomes[t],
                        "p50_ms": m["tenants"][t]["p50_ms"]}
                    for t in specs},
        "requests_per_tenant": requests,
        "window_steps": window_steps,
        "window_row_ratio": ratio,
        "weight_ratio": 3.0,
        "wall_s": wall_s,
        "all_served": all(o["served"] == requests
                          for o in outcomes.values()),
        "fairness": m["fairness"],
    }


def run(smoke: bool = False, out: str = "BENCH_coldstart.json") -> dict:
    import jax

    buckets = (1, 2) if smoke else (1, 2, 4)
    rows = []
    with tempfile.TemporaryDirectory(prefix="coldstart_") as keep_dir:
        for name, variant in WORKLOADS:
            rows.append(bench_workload(name, variant, mode="auto",
                                       buckets=buckets,
                                       keep_dir=keep_dir))
    tenant_row = bench_multitenant(requests=8 if smoke else 16)

    speedups = [r["artifact_speedup"] for r in rows]
    summary = {
        "n_workloads": len(rows),
        "min_artifact_speedup": min(speedups),
        "speedup_floor": 5.0,
        "zero_trace_boots": all(
            r["artifact_warm"]["trace_count"] == 0 for r in rows),
        "all_served": (all(r["artifact_warm"]["outcome"] == "served"
                           for r in rows)
                       and tenant_row["all_served"]),
        "ok": (min(speedups) >= 5.0
               and all(r["artifact_warm"]["trace_count"] == 0
                       for r in rows)),
    }
    report = {
        "device": f"{jax.default_backend()}:"
                  f"{jax.devices()[0].device_kind}",
        "n_devices": len(jax.devices()),
        "smoke": smoke,
        "workloads": rows,
        "multitenant": tenant_row,
        "summary": summary,
    }
    report = write_bench(out, report)

    emit([{
        "workload": r["workload"],
        "cold_s": r["cold"]["boot_s"],
        "warm_s": r["autotune_warm"]["boot_s"],
        "artifact_s": r["artifact_warm"]["boot_s"],
        "speedup": r["artifact_speedup"],
        "aot_traces": r["artifact_warm"]["trace_count"],
    } for r in rows], "§Cold start: boot-to-first-response")
    print(f"wrote {out} (min artifact speedup "
          f"{summary['min_artifact_speedup']:.1f}x, zero-trace="
          f"{summary['zero_trace_boots']}, ok={summary['ok']})")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.coldstart_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; still writes BENCH_coldstart.json")
    ap.add_argument("--child", action="store_true",
                    help="internal: one measured boot in this process")
    ap.add_argument("--workload"), ap.add_argument("--variant")
    ap.add_argument("--mode", default="auto")
    ap.add_argument("--buckets", default="1,2")
    ap.add_argument("--artifact", default=None,
                    help="child: boot from this artifact directory")
    ap.add_argument("--export", default=None,
                    help="child: export an artifact here instead of booting")
    args = ap.parse_args(argv)
    if args.child:
        _child(args)
        return
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
