"""Graph-runtime plan report: per-layer backend winners + arena memory plan.

The Fig-5-style layer breakdown, engine edition: lower YOLOv2-Tiny through
the graph runtime, autotune every dispatchable node (which backend wins
*where* — popcount vs ±1-matmul is shape-dependent, see the crossover
harness), and emit

* one row per dispatchable node: shape, winning backend, candidate timings;
* the static memory plan: per-buffer arena offsets, peak vs naive bytes —
  the §VI memory-bandwidth discipline as a planned number instead of a
  hope.

Input resolution is scaled 1/4 (as fig5_layers does) to keep host timings
tractable; channel dims are exact.
"""

from __future__ import annotations

import functools

import jax

from benchmarks.common import emit
from repro.core import bnn_model, converter
from repro.models import paper_nets
from repro.runtime import (Autotuner, chain_report, fuse_pool_epilogue,
                           infer_types, lower_packed, partition_chains,
                           plan_memory)
from repro.runtime.autotune import _node_signature

_HW = 104  # 416 / 4
_BATCH = 1


@functools.lru_cache(maxsize=None)
def _tuned(net: str):
    """(graph, types, tuner, choices) for ``net`` at the scaled resolution;
    cached so fig5_layers and run() share one tuning sweep."""
    spec, _ = paper_nets.get(net)
    params = bnn_model.init_params(jax.random.key(0), spec)
    packed = converter.convert(params, spec, (_HW, _HW))
    # The serving graph: conv+pool pairs fused (engine applies the same
    # pass), so winners/arena rows match what the engine executes.
    graph = fuse_pool_epilogue(lower_packed(spec, packed, (_HW, _HW)))
    in_shape = (_BATCH, _HW, _HW, spec[0].c_in)
    types = infer_types(graph, in_shape)
    # persist=False: report *this* run's measurements, never warm-start
    # stale winners from ~/.cache/repro/autotune.json.
    tuner = Autotuner(candidates=("xla", "xla_pm1"), warmup=1, iters=2,
                      persist=False)
    choices = tuner.tune(graph, in_shape)
    return graph, in_shape, types, tuner, choices


def conv_winners(net: str = "yolov2-tiny") -> list[str]:
    """Winning backend per dispatchable conv/dense node, in topo order —
    what fig5_layers joins onto its per-layer breakdown."""
    graph, _, _, _, choices = _tuned(net)
    return [choices[nid] for nid in graph.topo_order() if nid in choices]


def run(net: str = "yolov2-tiny") -> list[dict]:
    graph, in_shape, types, tuner, choices = _tuned(net)

    rows = []
    for nid in graph.topo_order():
        node = graph.nodes[nid]
        if nid not in choices:
            continue
        t = types[nid]
        entry = tuner.cache[_node_signature(
            node, types[node.inputs[0]].shape, tuner.candidates)]
        row = dict(node=nid, op=node.op,
                   out_shape="x".join(map(str, t.shape)),
                   channels=node.attrs.get("channels"),
                   backend=choices[nid])
        for b, ms in entry["timings_ms"].items():
            row[f"{b}_ms"] = ms
        rows.append(row)
    emit(rows, f"Graph plan — per-node backend winners, {net} "
               f"@{_HW}x{_HW} (host)")

    plan = plan_memory(graph, in_shape, types=types)
    mem_rows = plan.report()
    emit(mem_rows, f"Graph plan — arena assignment, {net} "
                   f"(peak {plan.peak_bytes()} B vs naive "
                   f"{plan.naive_bytes()} B, "
                   f"{plan.naive_bytes() / max(plan.peak_bytes(), 1):.2f}x "
                   f"reuse)")

    # Chain-fusion regions (DESIGN.md §9): which runs fuse into single
    # megakernel calls, their VMEM arena plans, and the HBM boundary
    # traffic each region removes vs the per-node path.
    chains = partition_chains(graph, in_shape, types=types)
    region_rows = chain_report(chains)
    total_avoided = sum(r["hbm_bytes_avoided"] for r in region_rows)
    emit(region_rows, f"Graph plan — megakernel regions, {net} "
                      f"({len(region_rows)} chains, {total_avoided} HBM "
                      f"bytes avoided per forward)")
    return rows


if __name__ == "__main__":
    run()
