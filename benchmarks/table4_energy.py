"""Paper Tab IV: energy per frame (YOLOv2-Tiny).

Energy cannot be measured on this host (the paper used Trepn on a phone;
we target TPU v5e).  We reproduce the table as a MODEL, clearly labelled:

    E/frame = P_chip × t_frame,   t_frame from the roofline bound of the
    dry-run (dominant term), P_chip = v5e TDP midpoint (~185 W).

The paper's metric is FPS/W; the reproducible claim is the RELATIVE
efficiency of binary vs float execution: the binary engine moves ~32×
fewer weight bytes and ~10-60× less conv compute, so its modeled
energy/frame scales down by the same runtime ratio measured in Table III
(energy ≈ power × time at comparable utilization — the paper's own
Tab IV shows power varying only 2-4× while FPS/W moves 24-5263×, i.e.
time dominates energy exactly as this model assumes).
"""

from __future__ import annotations

from benchmarks.common import emit
from benchmarks.table3_runtime import run as run_t3
from repro.launch.analysis import CHIP_WATTS

PAPER = {  # Tab IV, Snapdragon 820, YOLOv2 Tiny
    "cnndroid-gpu": dict(watts_mw=573, fps_per_w=1.18),
    "tflite-cpu-quant": dict(watts_mw=452, fps_per_w=4.40),
    "phonebit": dict(watts_mw=225.67, fps_per_w=105.26),
}


def run(t3_rows: list[dict] | None = None) -> list[dict]:
    t3_rows = t3_rows or run_t3()
    rows = []
    for r in t3_rows:
        t_float = r["float_ms"] / 1e3
        t_bnn = r["bnn_pm1_ms"] / 1e3
        rows.append(dict(
            network=r["network"],
            float_j_per_frame=round(CHIP_WATTS * t_float, 3),
            bnn_j_per_frame=round(CHIP_WATTS * t_bnn, 3),
            bnn_fps_per_w=round(1.0 / (CHIP_WATTS * t_bnn), 3),
            float_fps_per_w=round(1.0 / (CHIP_WATTS * t_float), 3),
            efficiency_gain=round(t_float / t_bnn, 2),
            paper_gain_vs_gpu=round(
                PAPER["phonebit"]["fps_per_w"]
                / PAPER["cnndroid-gpu"]["fps_per_w"], 1),
        ))
    emit(rows, "Table IV — modeled energy (E = P_chip × t_roofline), "
               "relative efficiency binary vs float")
    return rows


if __name__ == "__main__":
    run()
