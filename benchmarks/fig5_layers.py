"""Paper Fig 5: per-layer speedup on YOLOv2-Tiny.

The paper measures each conv layer of YOLOv2-Tiny under PhoneBit vs
CNNdroid-GPU: conv1 ~23× (bit-plane split overhead), conv2-conv8 ~45×
(up to 62×), conv9 ~3× (stays float).  We time each layer of the SAME
network on both engines — the packed integer path vs the float conv path —
at layer-appropriate shapes, reproducing the *shape* of Fig 5: first layer
< middle binary layers >> float conv9.

Each layer is timed standalone: conv1 through the bit-plane path, middle
convs as packed binary conv on packed ±1 input, conv9 as the float head.
The host CPU rides the pm1 (matmul-engine) mode — see table3's docstring
for the xor-mode caveat.  The analytic ops-bound column (32× middle, 4×
conv1 = 32/8 planes, 1× conv9) is the hardware-transferable shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from benchmarks.common import emit, time_fn
from repro.core import binary_conv, layer_integration, packing
from repro.core.bnn_model import BConv, FloatConv
from repro.kernels import ops as kops
from repro.models import paper_nets

PAPER_SPEEDUP = {  # digitized from Fig 5
    "conv1": 23.0, "conv2": 45.0, "conv3": 45.0, "conv4": 45.0,
    "conv5": 45.0, "conv6": 45.0, "conv7": 45.0, "conv8": 62.0,
    "conv9": 3.0,
}

# Spatial grid entering each conv at full 416 res, scaled by 1/4 to keep
# the CPU float baselines tractable (channel dims stay exact).
_SIZES = [416, 208, 104, 52, 26, 13, 13, 13, 13]
_SCALE = 4


def _float_conv_ms(x_float, w, stride, pad):
    f = jax.jit(lambda xx, ww: lax.conv_general_dilated(
        xx, ww, (stride, stride), [(pad, pad)] * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
    return time_fn(f, x_float, w) * 1e3


def run() -> list[dict]:
    spec, _ = paper_nets.get("yolov2-tiny")
    convs = [l for l in spec if isinstance(l, (BConv, FloatConv))]
    # Per-layer backend winners from the graph runtime's autotuner
    # (benchmarks/graph_plan.py): which engine won *where*.
    from benchmarks import graph_plan
    try:
        winners = graph_plan.conv_winners("yolov2-tiny")
    except Exception:
        winners = []
    rows = []
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    for i, (layer, size) in enumerate(zip(convs, _SIZES), start=1):
        h = max(size // _SCALE, 4)
        lname = f"conv{i}"
        c_in, c_out = layer.c_in, layer.c_out
        kk = layer.kernel
        w = jax.random.normal(key, (kk, kk, c_in, c_out), jnp.float32)
        x_pm1 = jnp.asarray(rng.choice([-1.0, 1.0],
                                       (1, h, h, c_in)).astype(np.float32))

        t_float = _float_conv_ms(x_pm1, w, layer.stride, layer.pad)

        if isinstance(layer, BConv) and layer.first:
            # bit-plane path on uint8 input
            x_u8 = jnp.asarray(rng.integers(0, 256, (1, h, h, c_in),
                                            dtype=np.uint8))
            planes = jax.jit(kops.bitplane_pack)
            wp = binary_conv.pack_conv_weights(w)  # packed per-plane below
            cw = packing.num_words(c_in)
            ww = jnp.tile(jnp.repeat(
                jnp.left_shift(jnp.int32(1), jnp.arange(8)), cw), kk * kk)

            def first_fwd(x):
                import repro.core.bitplanes as bp
                pl = bp.pack_bitplanes(x)
                n, hh, wwd, np_, cw_ = pl.shape
                flat = pl.reshape(n, hh, wwd, np_ * cw_)
                wpp = jnp.repeat(
                    packing.pack_signs(w, axis=2)[:, :, None], 8, axis=2)
                wpp = jnp.transpose(wpp, (4, 0, 1, 2, 3)).reshape(c_out, -1)
                return binary_conv.binary_conv2d_counts(
                    flat, wpp, kk, kk, layer.stride, layer.pad,
                    word_weights=ww)

            t_bnn = time_fn(jax.jit(first_fwd), x_u8) * 1e3
            ops_bound = 32.0 / 8.0
        elif isinstance(layer, BConv):
            xp = packing.pack_signs(x_pm1, axis=-1)
            wp = binary_conv.pack_conv_weights(w)
            thr = layer_integration.IntegratedParams(
                jnp.full((c_out,), kk * kk * c_in // 2, jnp.int32),
                jnp.zeros((c_out,), bool))

            def mid_fwd(xx, wpp):
                return binary_conv.binary_conv2d_fused(
                    xx, wpp, thr, kk, kk, layer.stride, layer.pad,
                    impl="pm1")

            t_bnn = time_fn(jax.jit(mid_fwd), xp, wp) * 1e3
            ops_bound = 32.0
        else:  # conv9: stays float in both engines (SIMD dot, paper ~3x)
            t_bnn = t_float
            ops_bound = 1.0

        graph_backend = ("float" if not isinstance(layer, BConv)
                         else (winners[i - 1] if i - 1 < len(winners)
                               else "n/a"))
        rows.append(dict(
            layer=lname, grid=h, c_in=c_in, c_out=c_out,
            float_ms=round(t_float, 3), bnn_ms=round(t_bnn, 3),
            host_speedup=round(t_float / max(t_bnn, 1e-9), 2),
            ops_bound_speedup=ops_bound,
            paper_speedup=PAPER_SPEEDUP[lname],
            graph_backend=graph_backend,
        ))
    emit(rows, "Fig 5 — per-layer speedup, YOLOv2-Tiny "
               "(host wall + ops-bound shape)")
    return rows


if __name__ == "__main__":
    run()
