"""End-to-end workload benchmark: image-in -> labels/boxes-out per backend.

Times the *whole* workload path (DESIGN.md §8) — letterbox / center-crop
preprocessing of an off-network-size frame, the packed forward through
the graph runtime, and the jit-compiled postprocess head (top-k or
YOLO decode + NMS) — for each executor backend, and writes the
machine-readable ``BENCH_workloads.json`` perf artifact.  The breakdown
columns (pre/forward/post) show where each backend's latency actually
goes: the decode + NMS head is a fixed cost, so backend wins on the
forward translate almost 1:1 to image->boxes wins.  Each column is an
independently timed median (``common.time_fn``), so on a shared host the
parts need not sum exactly to ``e2e_ms`` — compare within a column.

Paper nets run at paper resolution where that is tractable on the host
(AlexNet 227, YOLOv2-Tiny 416 — cf. ``table3_runtime``'s CPU caveat);
VGG16 and the interpret-mode Pallas backends run on the conformance-
scale tiny variants, same topology class, honest label in the rows.

    PYTHONPATH=src python -m benchmarks.workloads_bench [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_bench
from benchmarks.timing import time_fn


def bench_case(name: str, *, variant: str, backends: tuple[str, ...],
               input_hw=None, iters: int = 3, warmup: int = 1
               ) -> list[dict]:
    from repro import workloads

    rows = []
    rng = np.random.default_rng(0)
    for backend in backends:
        wl = workloads.get(name, variant=variant, matmul_mode=backend,
                           input_hw=input_hw)
        h, w = wl.input_hw
        # Off-network frame size: preprocessing does real resize work.
        raw = jnp.asarray(rng.integers(0, 256, (h + h // 2, 2 * w, 3)),
                          jnp.uint8)
        pre = jax.jit(wl.preprocess)
        x = pre(raw)[None]
        feat = wl.engine.raw(x)
        head = jax.jit(wl.postprocess)

        def e2e(img):
            return wl.engine(pre(img)[None])

        kw = dict(warmup=warmup, iters=iters)
        row = dict(
            workload=wl.name, task=wl.task, backend=backend,
            input_hw=h, raw_hw=int(raw.shape[0]),
            pre_ms=time_fn(pre, raw, **kw) * 1e3,
            fwd_ms=time_fn(wl.engine.raw, x, **kw) * 1e3,
            post_ms=time_fn(head, feat, **kw) * 1e3,
            e2e_ms=time_fn(e2e, raw, **kw) * 1e3,
        )
        rows.append(row)
    return rows


def run(smoke: bool = False, out: str = "BENCH_workloads.json") -> dict:
    if smoke:
        cases = [
            dict(name="alexnet_imagenet", variant="tiny",
                 backends=("xla", "xla_pm1")),
            dict(name="vgg16_imagenet", variant="tiny",
                 backends=("xla", "xla_pm1")),
            dict(name="yolov2_tiny_voc", variant="tiny",
                 backends=("xla", "xla_pm1", "vpu_direct_pool",
                           "vpu_chain")),
        ]
    else:
        cases = [
            dict(name="alexnet_imagenet", variant="paper",
                 backends=("xla", "xla_pm1", "mxu_pm1"), iters=2),
            dict(name="vgg16_imagenet", variant="tiny",
                 backends=("xla", "xla_pm1", "mxu_pm1", "vpu_popcount",
                           "vpu_direct", "vpu_direct_pool", "vpu_chain")),
            dict(name="yolov2_tiny_voc", variant="paper", input_hw=416,
                 backends=("xla", "xla_pm1", "mxu_pm1"), iters=2),
            dict(name="yolov2_tiny_voc", variant="tiny",
                 backends=("xla", "xla_pm1", "vpu_popcount",
                           "vpu_direct", "vpu_direct_pool", "vpu_chain")),
        ]
    rows: list[dict] = []
    for c in cases:
        rows += bench_case(c.pop("name"), **c)

    emit([{k: (f"{v:.3f}" if isinstance(v, float) else v)
           for k, v in r.items()} for r in rows],
         "§Workloads: image-in -> predictions-out latency per backend")

    winners = {}
    for r in rows:
        key = f"{r['workload']}@{r['input_hw']}"
        if key not in winners or r["e2e_ms"] < winners[key]["e2e_ms"]:
            winners[key] = dict(backend=r["backend"], e2e_ms=r["e2e_ms"])
    report = {
        "device": f"{jax.default_backend()}:"
                  f"{jax.devices()[0].device_kind}",
        "smoke": smoke,
        "rows": rows,
        "summary": {
            "n_rows": len(rows),
            "winners": winners,
        },
    }
    report = write_bench(out, report)
    print(f"wrote {out} ({len(rows)} rows; winners: "
          + ", ".join(f"{k}:{v['backend']}" for k, v in winners.items())
          + ")")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.workloads_bench")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny conformance variants only; still writes "
                         "BENCH_workloads.json")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
