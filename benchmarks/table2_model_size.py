"""Paper Tab II: model size, full-precision CNN vs packed BNN artifact.

Reproduces the compression-ratio claim (paper: 19.6× mean; AlexNet
249.5→16.3 MB, YOLOv2-Tiny 63.4→2.4 MB, VGG16 553.4→32.1 MB).  Our sizes
derive from the same architectures at the paper's shapes; the float column
is fp32 weights, the BNN column is the converted PhoneBit artifact
(1 bit/weight for binarized layers + f32 for the kept-float head + int
thresholds).

Accuracy columns of Tab II are training outcomes on CIFAR10/VOC2007 —
reproducing them needs the real datasets + long training, out of scope
here (synthetic-data training of the same nets is exercised by
examples/train_bnn_cifar.py); the paper's own numbers are cited inline.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core import bnn_model, converter
from repro.models import paper_nets

PAPER_MB = {  # (float, bnn, float_acc, bnn_acc) from Tab II
    "alexnet": (249.5, 16.3, 89.0, 87.2),
    "yolov2-tiny": (63.4, 2.4, 57.1, 51.7),
    "vgg16": (553.4, 32.1, 92.5, 87.8),
}


def run() -> list[dict]:
    rows = []
    for name in ("alexnet", "yolov2-tiny", "vgg16"):
        spec, (h, w, c) = paper_nets.get(name)
        params = bnn_model.init_params(jax.random.key(0), spec)
        packed = converter.convert(params, spec, (h, w))
        fb = converter.float_model_bytes(params) / 2**20
        bb = converter.model_bytes(packed) / 2**20
        pf, pb, _, _ = PAPER_MB[name]
        rows.append(dict(
            network=name,
            float_mb=round(fb, 1), bnn_mb=round(bb, 1),
            ratio=round(fb / bb, 1),
            paper_float_mb=pf, paper_bnn_mb=pb,
            paper_ratio=round(pf / pb, 1),
        ))
    emit(rows, "Table II — model size (MB) and compression ratio")
    return rows


if __name__ == "__main__":
    run()
