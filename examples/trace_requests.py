"""Tracing a serving burst: spans, flight records, Chrome trace export.

    PYTHONPATH=src python examples/trace_requests.py

The observability layer (DESIGN.md §10) instruments the production serve
path without touching the compiled executables: spans are host-side
scopes around the blocking boundaries, so enabling tracing changes no
jit trace and no served bit.  This example demonstrates both capture
modes on a YOLOv2-Tiny burst (resolution reduced from the paper's 416 —
the net is fully convolutional, so only the grid changes):

1. **Serve-path spans** — submit → assemble → stage → dispatch → device
   → scatter for every batch of a request burst through the
   ``InferenceServer``, with the per-bucket compile spans from
   ``compile_buckets()``.  While tracing, results stay bit-exact vs the
   flat-path oracle (``cross_check``) and ``engine.trace_count`` stays
   exactly where precompilation left it.
2. **Per-node / per-region spans** — ``GraphExecutor.traced_call`` walks
   the schedule host-side, blocking after each node, so each ``node.*``
   / ``region.*`` span carries real wall time.  On a ``vpu_chain``
   engine the fused conv runs appear as single ``region.*`` spans.

The export is Chrome trace-event JSON — load it at ``chrome://tracing``
or https://ui.perfetto.dev — and ``validate_trace`` is the same schema
check CI's obs-smoke job runs.
"""

import numpy as np

from repro.models import paper_nets
from repro.obs import trace
from repro.serving import InferenceServer, PhoneBitEngine

HW = 32      # reduced from 416 for the CPU demo
OUT = "trace_requests.json"

spec, (h, w, c), params = paper_nets.init("yolov2-tiny")
engine = PhoneBitEngine.from_trained(params, spec, (HW, HW),
                                     matmul_mode="xla_pm1")
server = InferenceServer(engine, max_batch=4, max_wait_s=0.0,
                         buckets=(1, 2, 4))

tracer = trace.install()                    # tracing ON from here

# ---- Part 1: serve a burst under tracing ---------------------------------
server.compile_buckets()                    # compile.bucket spans
t0 = engine.trace_count
rng = np.random.default_rng(0)
images = [rng.integers(0, 256, (HW, HW, 3), dtype=np.uint8)
          for _ in range(8)]
reqs = [server.submit(img) for img in images]
server.drain()

assert all(r.done for r in reqs)
assert engine.trace_count == t0, "tracing must never retrace"
# Tracing changes no served bit: the graph path still matches the flat
# packed_forward oracle on a full bucket.
batch = np.stack(images[:4])
engine.cross_check(batch)
m = server.metrics()
print(f"[serve] {m['served']} served, p50 {m['p50_ms']:.1f} ms; "
      f"flight tail: {[r['outcome'] for r in server.flight.last(3)]}")

# ---- Part 2: per-node / per-region execution spans -----------------------
chain_engine = PhoneBitEngine.from_trained(params, spec, (HW, HW),
                                           matmul_mode="vpu_chain")
exe = chain_engine.compile(1)
x = images[0][None]
got = exe.traced_call(x)                    # node.* / region.* spans
np.testing.assert_array_equal(np.asarray(got), np.asarray(exe(x)))

# ---- export + validate ---------------------------------------------------
trace.uninstall()                           # tracing OFF again
doc = tracer.export(OUT)
complete = trace.validate_trace(doc)        # schema + nesting check

names = {e["name"] for e in doc["traceEvents"]}
assert {"serve.assemble", "serve.stage", "serve.dispatch", "serve.device",
        "serve.scatter", "compile.bucket"} <= names, names
assert any(n.startswith("node.") for n in names), names
assert any(n.startswith("region.") for n in names), names

by_cat: dict = {}
for e in complete:
    by_cat[e["cat"]] = by_cat.get(e["cat"], 0) + 1
print(f"[trace] {len(doc['traceEvents'])} events "
      f"({len(complete)} spans) -> {OUT}; by kind: {by_cat}")
node_spans = sorted((e for e in complete
                     if e["name"].startswith(("node.", "region."))),
                    key=lambda e: -e["dur"])
for e in node_spans[:5]:
    print(f"  {e['name']:<28s} {e['dur'] / 1e3:8.2f} ms  {e['args']}")
print("OK — open the file at chrome://tracing or ui.perfetto.dev")
