"""Inspect one multi-pod lowering: mesh, shardings, collectives.

    PYTHONPATH=src python examples/multipod_lowering.py [arch] [shape]

Builds the 2×16×16 (512-chip) production mesh out of placeholder host
devices, lowers one (arch × shape) training/serving step against it, and
prints the memory analysis plus the collective-op census — the same
machinery the full dry-run sweep runs over all 40 cells.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ["REPRO_DRYRUN_F32"] = "1"

import sys

from repro.distributed.sharding import rules_for_mesh
from repro.launch import analysis, cells
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1] if len(sys.argv) > 1 else "vit-l16"
shape = sys.argv[2] if len(sys.argv) > 2 else "serve_b128"

mesh = make_production_mesh(multi_pod=True)
print(f"mesh: {dict(mesh.shape)} = {mesh.size} chips")
rules = rules_for_mesh(mesh)
build = cells.build_cell(arch, shape, rules)
with mesh:
    lowered = build.lower()
    compiled = lowered.compile()
print(compiled.memory_analysis())
m = analysis.collect(compiled, mesh.size)
print(f"per-device: {m['flops'] / 1e9:.2f} GFLOP, "
      f"{m['bytes'] / 2**30:.2f} GiB accessed, "
      f"{m['wire'] / 2**20:.1f} MiB wire")
print("collectives:", m["counts"])
