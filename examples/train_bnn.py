"""End-to-end BNN training -> conversion -> deployment (paper pipeline).

    PYTHONPATH=src python examples/train_bnn.py [--steps 300]

Trains a small CIFAR10-shaped BNN with the straight-through estimator
(latent float weights, Courbariaux et al.), exactly the kind of model the
PhoneBit engine serves; then converts and verifies the deployed engine
agrees with the trained float oracle, and reports the Tab-II-style
compression.  Data is synthetic (no datasets in this environment) — the
training dynamics (loss decreasing through binarized layers) are real.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize, bnn_model
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.serving import PhoneBitEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    spec = [
        BConv(3, 32, kernel=3, stride=1, pad=1, first=True),
        Pool(2, 2),
        BConv(32, 64, kernel=3, stride=1, pad=1),
        Pool(2, 2),
        BDense(8 * 8 * 64, 128),
        FloatDense(128, 10),
    ]
    params = bnn_model.init_params(jax.random.key(0), spec)
    opt = adamw_init(params)
    lr = cosine_schedule(args.lr, warmup=20, total=args.steps)

    def loss_fn(p, x, y):
        logits = bnn_model.float_forward(p, spec, x, train=True)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    @jax.jit
    def step(p, o, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, o, m = adamw_update(p, grads, o, lr=lr, weight_decay=0.0,
                               clip_latent_paths=lambda path: "w" in path)
        return p, o, loss

    rng = np.random.default_rng(0)
    # fixed synthetic "dataset": 10 class prototypes + noise
    protos = rng.integers(0, 256, (10, 32, 32, 3)).astype(np.float32)
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        y = rng.integers(0, 10, (args.batch,))
        x = protos[y] + rng.normal(0, 25, (args.batch, 32, 32, 3))
        x = jnp.asarray(np.clip(x, 0, 255).astype(np.uint8))
        params, opt, loss = step(params, opt, x, jnp.asarray(y))
        losses.append(float(loss))
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}")
    print(f"trained {args.steps} steps in {time.time() - t0:.1f}s: "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-20:]):.3f}")

    # deploy (Fig 2): convert + verify + measure
    engine = PhoneBitEngine.from_trained(params, spec, (32, 32))
    y = rng.integers(0, 10, (64,))
    x = jnp.asarray(np.clip(protos[y] + rng.normal(0, 25, (64, 32, 32, 3)),
                            0, 255).astype(np.uint8))
    pred_engine = np.asarray(jnp.argmax(engine(x), -1))
    pred_oracle = np.asarray(jnp.argmax(
        bnn_model.float_forward(params, spec, x), -1))
    agree = (pred_engine == pred_oracle).mean()
    acc = (pred_engine == y).mean()
    print(f"engine/oracle agreement: {agree:.1%}  "
          f"synthetic-class accuracy: {acc:.1%}")
    from repro.core import converter
    packed = converter.convert(params, spec, (32, 32))
    print(f"model size: float {converter.float_model_bytes(params)/2**20:.2f} MiB "
          f"-> packed {converter.model_bytes(packed)/2**20:.2f} MiB")
    assert agree == 1.0, "deployed engine must match its training oracle"


if __name__ == "__main__":
    main()
