"""Graph runtime tour: branching topology, passes, memory plan, autotune.

    PYTHONPATH=src python examples/graph_runtime.py

The flat ``LayerSpec`` list can only express straight-line networks.  The
operator IR (repro.runtime) has explicit edges, so branching topologies —
here a two-branch packed trunk concat'd in the packed domain — work with
the same fused kernels, the same memory planner, and the same autotuned
executor.  This example:

1. lowers trained params to the *unfused* IR and runs the optimization
   pass pipeline (layout assignment → BN integration (Eqns 5-9) →
   epilogue fusion → OR-pool absorption), printing the rewrites;
2. builds a branching graph (conv trunk → two parallel conv branches →
   packed concat → dense head) that no flat spec could express;
3. plans its arena memory and autotunes per-node backends;
4. cross-checks everything against composed float oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnn_model, converter
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
from repro.runtime import (Autotuner, Graph, GraphExecutor,
                           default_pipeline, lower_trained, plan_memory)

# ---------------------------------------------------------------- 1. passes
spec = [
    BConv(3, 32, 3, 1, 1, first=True), Pool(2, 2),
    BConv(32, 64, 3, 1, 1), Pool(2, 2),
    BDense(8 * 8 * 64, 128), FloatDense(128, 10),
]
params = bnn_model.init_params(jax.random.key(0), spec)

unfused = lower_trained(spec, params, (32, 32))
fused = default_pipeline(unfused)
print("unfused IR:", [unfused.nodes[i].op for i in unfused.topo_order()])
print("after pipeline:", [fused.nodes[i].op for i in fused.topo_order()])

x = jnp.asarray(np.random.default_rng(0).integers(0, 256, (2, 32, 32, 3),
                                                  dtype=np.uint8))
ref = bnn_model.float_forward(params, spec, x)
got = GraphExecutor(fused, "xla")(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-3)
print("pass pipeline output matches float oracle ✓\n")

# ------------------------------------------------------------ 2. branching
# trunk conv -> {branch A conv, branch B conv} -> concat -> binary dense
trunk_spec = [BConv(3, 64, 3, 1, 1, first=True)]
branch_a = [BConv(64, 64, 3, 1, 1)]
branch_b = [BConv(64, 128, 3, 1, 1)]
p_trunk = bnn_model.init_params(jax.random.key(1), trunk_spec)
p_a = bnn_model.init_params(jax.random.key(2), branch_a)
p_b = bnn_model.init_params(jax.random.key(3), branch_b)
k_trunk = converter.convert(p_trunk, trunk_spec, (16, 16))
k_a = converter.convert(p_a, branch_a, (16, 16))
k_b = converter.convert(p_b, branch_b, (16, 16))

g = Graph(input_hw=(16, 16))
inp = g.add("input", attrs=dict(channels=3))
g.input_id = inp
bp = g.add("bitplane_expand", [inp], attrs=dict(c_in=3, channels=3))
trunk = g.add("packed_conv", [bp],
              attrs=dict(kernel=3, stride=1, pad=1, channels=64,
                         first=True),
              params=dict(w_packed=k_trunk[0]["w_packed"],
                          thresh=k_trunk[0]["thresh"],
                          word_weights=k_trunk[0]["word_weights"]))
ba = g.add("packed_conv", [trunk],
           attrs=dict(kernel=3, stride=1, pad=1, channels=64, first=False),
           params=dict(w_packed=k_a[0]["w_packed"], thresh=k_a[0]["thresh"]))
bb = g.add("packed_conv", [trunk],
           attrs=dict(kernel=3, stride=1, pad=1, channels=128, first=False),
           params=dict(w_packed=k_b[0]["w_packed"], thresh=k_b[0]["thresh"]))
cat = g.add("concat_packed", [ba, bb], attrs=dict(channels=192))
g.output_id = cat

x16 = jnp.asarray(np.random.default_rng(1).integers(0, 256, (2, 16, 16, 3),
                                                    dtype=np.uint8))
out = GraphExecutor(g, "xla")(x16)
print(f"branching graph output: {out.shape} packed words "
      f"(64+128 channels -> {out.shape[-1]} words) ✓")
# oracle: each branch alone must equal the branch-restricted subgraph
g_a = Graph(nodes={n: g.nodes[n] for n in (inp, bp, trunk, ba)},
            input_id=inp, output_id=ba, input_hw=(16, 16)).copy()
np.testing.assert_array_equal(
    np.asarray(GraphExecutor(g_a, "xla")(x16)),
    np.asarray(out[..., :2]))  # first 64 channels = 2 words
print("branch A slice matches its standalone subgraph ✓")

# ---------------------------------------------------- 3. plan and autotune
plan = plan_memory(g, (2, 16, 16, 3))
print(f"arena: peak {plan.peak_bytes()} B, naive {plan.naive_bytes()} B "
      f"({plan.naive_bytes() / plan.peak_bytes():.2f}x reuse)")
tuner = Autotuner(candidates=("xla", "xla_pm1"))
ex = tuner.tuned_executor(g, (2, 16, 16, 3))
print("autotuned backends:", [(r["op"], r["backend"])
                              for r in ex.backend_report()])
np.testing.assert_array_equal(np.asarray(ex(x16)), np.asarray(out))
print("autotuned executor bit-exact vs fixed-backend executor ✓")
