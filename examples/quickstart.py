"""Quickstart: the PhoneBit deployment flow in ~40 lines (paper Fig 2/3).

    PYTHONPATH=src python examples/quickstart.py

1. define a small BNN (conv/pool/dense spec, first layer bit-plane),
2. convert trained (here: random) float params to the packed artifact,
3. save + reload the compressed artifact,
4. run packed integer inference and check it against the float oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnn_model
from repro.core.bnn_model import BConv, BDense, FloatDense, Pool
from repro.serving import PhoneBitEngine

# (1) network spec — the Fig 3 conv/pool/dense calls, declaratively
spec = [
    BConv(3, 64, kernel=3, stride=1, pad=1, first=True),   # bit-plane input
    Pool(2, 2),
    BConv(64, 128, kernel=3, stride=1, pad=1),             # xor+popcount
    Pool(2, 2),
    BDense(8 * 8 * 128, 256),                              # binary dense
    FloatDense(256, 10),                                   # float head
]
params = bnn_model.init_params(jax.random.key(0), spec)

# (2) offline conversion: fold BN -> integer thresholds, bit-pack weights
engine = PhoneBitEngine.from_trained(params, spec, input_hw=(32, 32))
print(f"packed model: {engine.model_bytes / 2**10:.1f} KiB "
      f"(float would be {sum(np.asarray(v).size * 4 for p in params for v in p.values()) / 2**10:.1f} KiB)")

# (3) the compressed artifact round-trips
engine.save_artifact("/tmp/quickstart_bnn.npz")
engine2 = PhoneBitEngine.from_artifact("/tmp/quickstart_bnn.npz", spec,
                                       (32, 32))

# (4) packed integer inference == float sign oracle.  The engine executes
# through the graph runtime (operator IR + jit'd topological executor);
# cross_check also runs the legacy flat packed_forward walk and asserts
# bit-exactness between the two.
x = jnp.asarray(np.random.default_rng(0).integers(
    0, 256, (4, 32, 32, 3), dtype=np.uint8))
logits = engine2.cross_check(x)
oracle = bnn_model.float_forward(params, spec, x)
np.testing.assert_allclose(np.asarray(logits), np.asarray(oracle),
                           rtol=1e-4, atol=1e-4)
print("packed engine (graph runtime == flat path) matches float oracle ✓")
print("logits[0]:", np.asarray(logits[0]).round(2))

# (5) the runtime's static memory plan: intermediate buffers share one
# arena (lifetime-based slot reuse), so peak memory < sum of buffers.
plan = engine2.memory_plan()
print(f"memory plan: peak {plan.peak_bytes() / 2**10:.1f} KiB arena vs "
      f"{plan.naive_bytes() / 2**10:.1f} KiB without reuse")
print("per-node backends:", [(r['op'], r['backend'])
                             for r in engine2.backend_choices])
