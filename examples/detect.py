"""Image -> boxes end-to-end: the YOLOv2-Tiny VOC detection workload.

    PYTHONPATH=src python examples/detect.py

Demonstrates the workload subsystem (DESIGN.md §8): one registry lookup
bundles the letterbox preprocessing, the converted BNN engine on the
graph runtime, and the jit-compiled YOLOv2 decode + fixed-size NMS head.
Arbitrary-size uint8 images stream through the production
``InferenceServer`` — preprocess runs at batch staging, forward + decode
run as one per-bucket precompiled executable, and each request's result
is a fixed-size array of ``[x1, y1, x2, y2, score, class]`` rows.

Weights are the seeded demo checkpoint (the repo has no trained VOC
weights), so the detections are structurally valid but semantically
random; the resolution is reduced from the paper's 416 to keep the CPU
demo fast — the net is fully convolutional, so only the grid changes.
"""

import numpy as np

from repro import workloads
from repro.workloads import DetectConfig

# Low score threshold: with random demo weights, objectness * class
# probability rarely clears the deployment default of 0.3.
workload = workloads.get(
    "yolov2_tiny_voc", input_hw=64,
    detect=DetectConfig(score_thresh=0.02, iou_thresh=0.45, max_det=8))
h, w = workload.input_hw
print(f"{workload.name}: packed model {workload.model_bytes / 2**20:.1f} "
      f"MiB, serving at {h}x{w} (paper: 416x416)")

server = workload.server(max_batch=4, max_wait_s=0.0, buckets=(1, 2, 4))
compile_s = server.compile_buckets()
print(f"compiled buckets {list(compile_s)} in "
      f"{sum(compile_s.values()):.2f}s; traces: "
      f"{workload.engine.trace_count}")

# "Camera frames" at assorted non-network sizes: the letterbox hook maps
# each onto the 64x64 network canvas at batch staging.
rng = np.random.default_rng(0)
sizes = [(120, 160), (96, 96), (48, 100), (200, 150), (64, 64)]
requests = [server.submit(rng.integers(0, 256, (sh, sw, 3), dtype=np.uint8))
            for sh, sw in sizes]
server.drain()
assert workload.engine.trace_count == len(compile_s) * 2  # zero retraces

m = server.metrics()
print(f"served {m['served']} frames, p50 {m['p50_ms']:.1f} ms, "
      f"p95 {m['p95_ms']:.1f} ms\n")
for (sh, sw), req in zip(sizes, requests):
    dets = workload.format(req.result)
    print(f"frame {sh}x{sw}: {len(dets)} boxes (network frame coords)")
    for d in dets[:3]:
        x1, y1, x2, y2 = d["box"]
        # Map the network-frame box back onto the original frame.
        ox1, oy1, ox2, oy2 = workloads.unletterbox_boxes(
            np.array(d["box"]), (sh, sw), (h, w))
        print(f"  {d['label']:<12} {d['score']:.3f}  "
              f"net [{x1:5.1f} {y1:5.1f} {x2:5.1f} {y2:5.1f}] -> "
              f"frame [{ox1:5.1f} {oy1:5.1f} {ox2:5.1f} {oy2:5.1f}]")
print("OK")
