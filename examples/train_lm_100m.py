"""End-to-end driver: train the ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 200]

Thin wrapper over the production launcher (repro.launch.train) with the
lm-100m config: checkpointing every 50 steps, straggler monitoring, and
resume-on-restart all active — the same path a cluster job would run, on
the host device.  (~15 s/step on this CPU; pass --steps 20 for a quick
look, the default 200 takes ~50 min.)
"""

import sys

from repro.launch import train

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--steps") for a in argv):
        argv += ["--steps", "200"]
    argv += ["--arch", "lm-100m", "--batch", "8", "--seq-len", "512",
             "--checkpoint-dir", "/tmp/lm100m_ckpt",
             "--checkpoint-every", "50"]
    train.main(argv)
