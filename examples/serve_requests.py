"""Batched request serving through the InferenceServer (paper engine + LM).

    PYTHONPATH=src python examples/serve_requests.py

Part 1 — PhoneBit engine behind the production server (DESIGN.md §7):
``compile_buckets()`` precompiles one executable per batch bucket (no
manual warm-up calls), then single-image requests stream through async
double-buffered dispatch — batch k+1 is on the device while batch k's
results scatter.  Requests carry deadlines; an overloaded queue sheds
instead of growing.

Part 2 — continuous-batching LM decode through the *same* protocol:
submit prompts, drain, read the same p50/p95/served/dropped metrics.
"""

import jax
import numpy as np

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serving import InferenceServer, PhoneBitEngine
from repro.serving.lm_server import LMServer

# ---- Part 1: BNN image serving ------------------------------------------
spec = [BConv(3, 64, kernel=3, stride=1, pad=1, first=True), Pool(2, 2),
        BConv(64, 128, kernel=3, stride=1, pad=1), Pool(2, 2),
        FloatDense(8 * 8 * 128, 10)]
params = bnn_model.init_params(jax.random.key(0), spec)
engine = PhoneBitEngine.from_trained(params, spec, (32, 32),
                                     matmul_mode="xla_pm1")
server = InferenceServer(engine, max_batch=8, max_wait_s=0.0,
                         buckets=(1, 2, 4, 8))
compile_s = server.compile_buckets()     # one executable per bucket
print(f"[bnn] compiled buckets {list(compile_s)} in "
      f"{sum(compile_s.values()):.2f}s; traces so far: "
      f"{engine.trace_count}")

rng = np.random.default_rng(0)
requests = [server.submit(rng.integers(0, 256, (32, 32, 3), dtype=np.uint8),
                          deadline_s=5.0)
            for _ in range(24)]
done = server.drain()
m = server.metrics()
assert engine.trace_count == len(server.scheduler.buckets)  # zero retraces
print(f"[bnn] served {m['served']} (dropped {m['dropped']}), "
      f"p50 {m['p50_ms']:.1f} ms, p95 {m['p95_ms']:.1f} ms, "
      f"{m['throughput']:.0f} img/s (async double-buffered)")

# ---- Part 2: LM continuous batching through the same protocol -------------
cfg = transformer.LMConfig(
    name="serve-demo", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab=512, tie_embeddings=True)
mesh = make_host_mesh(data=1, model=1)
rules = rules_for_mesh(mesh)
with mesh:
    lm_params = transformer.init_params(jax.random.key(1), cfg, ep=1)
    lm = LMServer(cfg=cfg, rules=rules, params=lm_params, n_slots=4,
                  max_seq=64)
    prompts = [list(rng.integers(1, cfg.vocab, 6)) for _ in range(3)]
    lm_reqs = [lm.submit(p, max_new=8) for p in prompts]
    lm.drain()
    lm_m = lm.metrics()
    toks = sum(len(r.result) for r in lm_reqs)
    print(f"[lm] served {lm_m['served']} prompts, {toks} tokens, "
          f"p50 {lm_m['p50_ms']:.0f} ms; "
          f"throughput {lm_m['throughput']:.1f} seq/s")
print("OK")
