"""Batched request serving through the scheduler (paper engine + LM).

    PYTHONPATH=src python examples/serve_requests.py

Part 1 — PhoneBit engine behind the BatchScheduler: submit single-image
requests, let the scheduler assemble padded buckets, measure latency and
throughput (the datacenter-front-end version of the paper's phone engine).

Part 2 — continuous-batching LM decode: multiple prompts share one
sequence-sharded KV cache via slot management.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnn_model
from repro.core.bnn_model import BConv, FloatDense, Pool
from repro.distributed.sharding import rules_for_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.serving import BatchScheduler, PhoneBitEngine
from repro.serving.lm_server import LMServer

# ---- Part 1: BNN image serving ------------------------------------------
spec = [BConv(3, 64, kernel=3, stride=1, pad=1, first=True), Pool(2, 2),
        BConv(64, 128, kernel=3, stride=1, pad=1), Pool(2, 2),
        FloatDense(8 * 8 * 128, 10)]
params = bnn_model.init_params(jax.random.key(0), spec)
engine = PhoneBitEngine.from_trained(params, spec, (32, 32),
                                     matmul_mode="xla_pm1")
sched = BatchScheduler(max_batch=8, max_wait_s=0.0, buckets=(1, 2, 4, 8))
rng = np.random.default_rng(0)

def run(payloads):
    return list(np.asarray(engine(jnp.asarray(np.stack(payloads)))))

run([rng.integers(0, 256, (32, 32, 3), dtype=np.uint8)] * 8)  # warmup
t0 = time.monotonic()
for _ in range(24):
    sched.submit(rng.integers(0, 256, (32, 32, 3), dtype=np.uint8))
done = 0
while len(sched):
    done += len(sched.drain(run))
dt = time.monotonic() - t0
print(f"[bnn] served {done} requests in {dt * 1e3:.0f} ms "
      f"({done / dt:.0f} img/s)")

# ---- Part 2: LM continuous batching ---------------------------------------
cfg = transformer.LMConfig(
    name="serve-demo", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_head=32, d_ff=256, vocab=512, tie_embeddings=True)
mesh = make_host_mesh(data=1, model=1)
rules = rules_for_mesh(mesh)
with mesh:
    lm_params = transformer.init_params(jax.random.key(1), cfg, ep=1)
    server = LMServer(cfg=cfg, rules=rules, params=lm_params, n_slots=4,
                      max_seq=64)
    prompts = [list(rng.integers(1, cfg.vocab, 6)) for _ in range(3)]
    t0 = time.monotonic()
    outs = [server.generate(p, max_new=8) for p in prompts]
    dt = time.monotonic() - t0
    toks = sum(len(o) for o in outs)
    print(f"[lm] generated {toks} tokens for {len(prompts)} prompts "
          f"in {dt:.2f}s ({toks / dt:.1f} tok/s); "
          f"cache utilization {server.manager.utilization:.0%}")
print("OK")
